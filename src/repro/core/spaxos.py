"""Compartmentalized S-Paxos (paper section 7).

S-Paxos separates *data flow* from *control flow*: client commands are
persisted on a majority of stabilizers by disseminators, and the MultiPaxos
leader orders only small command *ids*.  The compartmentalized deployment
(paper Fig. 27) adds proxy leaders, acceptor grids and scaled replicas.

Flow (write):
  client --cmd--> disseminator --cmd--> stabilizers (majority ack)
         disseminator --id--> leader --Phase2a(id)--> proxy --grid--> chosen
         proxy --Chosen(id)--> stabilizer --Chosen(cmd)--> replicas -> client

The leader never touches command payloads - only ids (the paper's point:
the leader stops being a bottleneck on the data path).
"""
from __future__ import annotations

import random
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .cluster import Network, Node
from .history import History
from .messages import (
    Chosen,
    ClientRequest,
    Command,
    Disseminate,
    FetchCommand,
    FetchReply,
    IdChosen,
    Phase2a,
    Phase2b,
    ProposeId,
    StabilizeAck,
    Timer,
)
from .protocols import BaseDeployment
from .quorums import GridQuorums, MajorityQuorums, QuorumSystem, pick_write_quorum
from .roles import Acceptor, Client, Leader, ProxyLeader, Replica
from .statemachine import make_state_machine


class Disseminator(Node):
    """Assigns ids, persists payloads on a majority of stabilizers, then
    hands the id to the leader for ordering."""

    def __init__(self, addr: str, dis_id: int, stabilizers: Sequence[str],
                 leader: str, seed: int = 0) -> None:
        super().__init__(addr)
        self.dis_id = dis_id
        self.stabilizers = list(stabilizers)
        self.majority = len(self.stabilizers) // 2 + 1
        self.leader = leader
        self.seq = 0
        # cmd_id -> (command, acks)
        self.pending: Dict[Tuple[int, int], Tuple[Command, Set[int]]] = {}
        self.rng = random.Random(seed * 193 + dis_id)

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            cmd_id = (self.dis_id, self.seq)
            self.seq += 1
            self.pending[cmd_id] = (msg.command, set())
            for s in self.stabilizers:
                self.send(s, Disseminate(cmd_id=cmd_id, command=msg.command))
        elif isinstance(msg, StabilizeAck):
            entry = self.pending.get(msg.cmd_id)
            if entry is None:
                return
            command, acks = entry
            acks.add(msg.stabilizer_id)
            if len(acks) == self.majority:  # fire exactly once
                self.send(self.leader, ProposeId(cmd_id=msg.cmd_id))


class Stabilizer(Node):
    """Persists command payloads; resolves chosen ids back to payloads and
    notifies the replicas (the data path's final hop)."""

    def __init__(self, addr: str, stab_id: int, peers: Sequence[str],
                 replicas: Sequence[str]) -> None:
        super().__init__(addr)
        self.stab_id = stab_id
        self.peers = [p for p in peers if p != addr]
        self.replicas = list(replicas)
        self.store: Dict[Tuple[int, int], Command] = {}
        # chosen ids whose payload we're still fetching: id -> slot
        self.waiting: Dict[Tuple[int, int], int] = {}

    def _deliver(self, slot: int, command: Command) -> None:
        for r in self.replicas:
            self.send(r, Chosen(slot=slot, value=command))

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, Disseminate):
            self.store[msg.cmd_id] = msg.command
            self.send(src, StabilizeAck(cmd_id=msg.cmd_id, stabilizer_id=self.stab_id))
            # late fetch satisfied locally
            if msg.cmd_id in self.waiting:
                self._deliver(self.waiting.pop(msg.cmd_id), msg.command)
        elif isinstance(msg, Chosen):
            # value is ("id", cmd_id): resolve payload -> replicas
            _, cmd_id = msg.value
            cmd = self.store.get(cmd_id)
            if cmd is not None:
                self._deliver(msg.slot, cmd)
            else:
                self.waiting[cmd_id] = msg.slot
                for p in self.peers:
                    self.send(p, FetchCommand(cmd_id=cmd_id, requester=self.addr))
        elif isinstance(msg, FetchCommand):
            self.send(msg.requester, FetchReply(cmd_id=msg.cmd_id,
                                                command=self.store.get(msg.cmd_id)))
        elif isinstance(msg, FetchReply):
            if msg.command is not None and msg.cmd_id in self.waiting:
                self.store[msg.cmd_id] = msg.command
                self._deliver(self.waiting.pop(msg.cmd_id), msg.command)


class SPaxosProxyLeader(ProxyLeader):
    """Proxy leader that routes Chosen(id) to one stabilizer (round-robin)
    instead of to the replicas - the replicas need payloads, not ids."""

    def __init__(self, addr: str, acceptors: Sequence[str], quorums: QuorumSystem,
                 stabilizers: Sequence[str], seed: int = 0) -> None:
        super().__init__(addr, acceptors, quorums, replicas=[], seed=seed)
        self.stabilizers = list(stabilizers)
        self._stab_rr = 0

    def _notify_chosen(self, msg) -> None:  # type: ignore[override]
        stab = self.stabilizers[self._stab_rr % len(self.stabilizers)]
        self._stab_rr += 1
        self.send(stab, msg)


class SPaxosDeployment(BaseDeployment):
    """Compartmentalized S-Paxos (paper Fig. 27)."""

    def __init__(
        self,
        f: int = 1,
        n_disseminators: int = 2,
        n_stabilizers: int = 3,  # 2f+1
        n_proxy_leaders: int = 3,
        grid: Optional[Tuple[int, int]] = (2, 2),
        n_replicas: int = 3,
        n_clients: int = 2,
        state_machine: str = "kv",
        consistency: str = "linearizable",
        seed: int = 0,
        latency_fn: Optional[Callable[[str, str], float]] = None,
    ) -> None:
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()

        if grid is not None:
            self.quorums: QuorumSystem = GridQuorums(rows=grid[0], cols=grid[1])
        else:
            self.quorums = MajorityQuorums(f=f)
        self.quorums.validate()

        self.acceptor_addrs = [f"acceptor/{i}" for i in range(self.quorums.n)]
        self.replica_addrs = [f"replica/{i}" for i in range(n_replicas)]
        self.proxy_addrs = [f"proxy/{i}" for i in range(n_proxy_leaders)]
        self.stab_addrs = [f"stabilizer/{i}" for i in range(n_stabilizers)]
        self.dis_addrs = [f"disseminator/{i}" for i in range(n_disseminators)]
        self.leader_addr = "leader/0"

        self.acceptors = [Acceptor(a, i) for i, a in enumerate(self.acceptor_addrs)]
        self.replicas = [
            Replica(addr, i, n_replicas, make_state_machine(state_machine), seed=seed)
            for i, addr in enumerate(self.replica_addrs)
        ]
        self.stabilizers = [
            Stabilizer(addr, i, self.stab_addrs, self.replica_addrs)
            for i, addr in enumerate(self.stab_addrs)
        ]
        self.proxies = [
            SPaxosProxyLeader(addr, self.acceptor_addrs, self.quorums,
                              self.stab_addrs, seed=seed)
            for addr in self.proxy_addrs
        ]
        self.disseminators = [
            Disseminator(addr, i, self.stab_addrs, self.leader_addr, seed=seed)
            for i, addr in enumerate(self.dis_addrs)
        ]
        self.leader = SPaxosLeader(self.leader_addr, 0, self.acceptor_addrs,
                                   self.quorums, self.proxy_addrs, seed=seed)
        self.clients = [
            Client(f"client/{i}", i, self.dis_addrs[i % n_disseminators],
                   self.acceptor_addrs, self.quorums, self.replica_addrs,
                   consistency=consistency, history=self.history, seed=seed)
            for i in range(n_clients)
        ]
        for group in (self.acceptors, self.replicas, self.stabilizers, self.proxies,
                      self.disseminators, [self.leader], self.clients):
            self.net.add_nodes(group)


class SPaxosLeader(Node):
    """Orders command *ids* only (never payloads)."""

    def __init__(self, addr: str, leader_id: int, acceptors: Sequence[str],
                 quorums: QuorumSystem, proxies: Sequence[str], seed: int = 0) -> None:
        super().__init__(addr)
        self.leader_id = leader_id
        self.quorums = quorums
        self.proxies = list(proxies)
        self.next_slot = 0
        self.ballot = 0
        self._proxy_rr = 0

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ProposeId):
            slot = self.next_slot
            self.next_slot += 1
            proxy = self.proxies[self._proxy_rr % len(self.proxies)]
            self._proxy_rr += 1
            self.send(proxy, Phase2a(slot=slot, ballot=self.ballot,
                                     value=("id", msg.cmd_id),
                                     leader_id=self.leader_id))


# ---------------------------------------------------------------------------
# Vanilla (fused-server) S-Paxos - paper Fig. 26 baseline
# ---------------------------------------------------------------------------


class VanillaSPaxosServer(Replica):
    """One fused vanilla-S-Paxos server: disseminator + stabilizer +
    acceptor + replica in a single process, with the MultiPaxos leader role
    colocated on server 0 - matching the fused accounting of
    ``vanilla_spaxos_model`` (one ``leader`` machine + ``n - 1`` followers
    sharing the dissemination/stabilization/acceptor/reply work uniformly).

    Wire behaviour mirrors the table term by term: ``Disseminate`` and
    ``Chosen`` broadcasts include the sender itself (the model counts
    self-sends - the in-process network accounts them on both sides), the
    leader self-broadcasts Phase 2 to a thrifty majority drawn from *all*
    ``n`` servers, and each server resolves a chosen id from its local
    store and hands the payload to its *local* replica component without a
    message - the one internal hop the table omits.  Total wire messages
    per command equal the table's total exactly; only the quorum draw moves
    acceptor messages between machines.
    """

    def __init__(self, addr: str, server_id: int, n_servers: int, f: int,
                 servers: Sequence[str], state_machine, seed: int = 0) -> None:
        super().__init__(addr, server_id, n_servers, state_machine, seed=seed)
        self.server_id = server_id
        self.n_servers = n_servers
        self.servers = list(servers)  # all n, self included
        self.leader_addr = servers[0]
        self.majority = n_servers // 2 + 1  # = f + 1
        self.role_rng = random.Random(seed * 193 + server_id)
        # disseminator component
        self.seq = 0
        self.dis_pending: Dict[Tuple[int, int], Set[int]] = {}
        # stabilizer component
        self.store: Dict[Tuple[int, int], Command] = {}
        self.waiting: Dict[Tuple[int, int], int] = {}  # cmd_id -> chosen slot
        # leader component (server 0 only)
        self.next_slot = 0
        self.ballot = 0
        self.pending2: Dict[int, Tuple[Tuple[int, int], Set[int]]] = {}

    def _deliver_local(self, slot: int, command: Command) -> None:
        """Payload resolved: hand to the local replica component (free)."""
        if slot not in self.log:
            self.log[slot] = command
            self._execute_ready()

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):  # disseminator: persist payload
            cmd_id = (self.server_id, self.seq)
            self.seq += 1
            self.dis_pending[cmd_id] = set()
            for s in self.servers:  # self included: the model counts it
                self.send(s, Disseminate(cmd_id=cmd_id, command=msg.command))
        elif isinstance(msg, Disseminate):  # stabilizer: store + ack
            self.store[msg.cmd_id] = msg.command
            self.send(src, StabilizeAck(cmd_id=msg.cmd_id,
                                        stabilizer_id=self.server_id))
            if msg.cmd_id in self.waiting:
                self._deliver_local(self.waiting.pop(msg.cmd_id), msg.command)
        elif isinstance(msg, StabilizeAck):
            acks = self.dis_pending.get(msg.cmd_id)
            if acks is None:
                return
            acks.add(msg.stabilizer_id)
            if len(acks) == self.majority:  # fire exactly once
                self.send(self.leader_addr, ProposeId(cmd_id=msg.cmd_id))
        elif isinstance(msg, ProposeId):  # leader: order the id
            slot = self.next_slot
            self.next_slot += 1
            members = self.role_rng.sample(range(self.n_servers),
                                           self.majority)
            self.pending2[slot] = (msg.cmd_id, set())
            for a in members:
                self.send(self.servers[a],
                          Phase2a(slot=slot, ballot=self.ballot,
                                  value=("id", msg.cmd_id),
                                  leader_id=0))
        elif isinstance(msg, Phase2a):  # acceptor: vote
            self.send(src, Phase2b(slot=msg.slot, ballot=msg.ballot,
                                   acceptor_id=self.server_id))
        elif isinstance(msg, Phase2b):  # leader: count the quorum
            entry = self.pending2.get(msg.slot)
            if entry is None:
                return
            cmd_id, acks = entry
            acks.add(msg.acceptor_id)
            if len(acks) == self.majority:
                del self.pending2[msg.slot]
                for s in self.servers:  # self included: the model counts it
                    self.send(s, Chosen(slot=msg.slot, value=("id", cmd_id)))
        elif isinstance(msg, Chosen):  # stabilizer: resolve id -> payload
            _, cmd_id = msg.value
            cmd = self.store.get(cmd_id)
            if cmd is not None:
                self._deliver_local(msg.slot, cmd)
            else:  # Chosen raced ahead of our Disseminate copy
                self.waiting[cmd_id] = msg.slot
        else:  # replica-component reads etc.
            super().on_message(src, msg)


class VanillaSPaxosDeployment(BaseDeployment):
    """n = 2f+1 fused S-Paxos servers; server 0 carries the leader role."""

    def __init__(
        self,
        f: int = 1,
        n_clients: int = 3,
        state_machine: str = "kv",
        consistency: str = "linearizable",
        seed: int = 0,
        latency_fn: Optional[Callable[[str, str], float]] = None,
    ) -> None:
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()
        n = 2 * f + 1
        self.n_servers = n
        self.server_addrs = [f"server/{i}" for i in range(n)]
        self.servers = [
            VanillaSPaxosServer(addr, i, n, f, self.server_addrs,
                                make_state_machine(state_machine), seed=seed)
            for i, addr in enumerate(self.server_addrs)
        ]
        quorums = MajorityQuorums(f=f)
        # client i disseminates through server i % n; n_clients should be a
        # multiple of n so the model's uniform dissemination share holds
        self.clients = [
            Client(f"client/{i}", i, self.server_addrs[i % n], [], quorums,
                   [], consistency=consistency, history=self.history,
                   seed=seed)
            for i in range(n_clients)
        ]
        self.net.add_nodes(self.servers)
        self.net.add_nodes(self.clients)
