"""Linearizability checking (paper section 3.5, Herlihy & Wing).

Two checkers:

* ``check_linearizable``: exhaustive Wing-Gong search (with memoisation) for
  small histories - the ground truth used by the hypothesis property tests.
  Handles pending invocations per the definition: the history may be
  *extended* with responses for pending ops (they may be linearized with any
  result) or pending ops may be dropped.

* ``check_slot_order`` / ``check_register_semantics``: the paper's own proof
  structure specialised to our protocol, which stamps every response with the
  log index it wrote to / read from.  If ``x <_H y`` then ``slot(x) <=
  slot(y)`` (strictly for write/write).  Cheap enough for large histories.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from .history import History, Operation
from .statemachine import StateMachine, make_state_machine


def _hashable(x: Any) -> Any:
    if isinstance(x, dict):
        return frozenset((k, _hashable(v)) for k, v in x.items())
    if isinstance(x, (list, tuple)):
        return tuple(_hashable(v) for v in x)
    return x


def check_linearizable(history: History, sm_kind: str = "kv",
                       max_nodes: int = 2_000_000) -> bool:
    """Exhaustive search for a linearization of ``history``.

    Completed operations must all be linearized with matching results;
    pending operations may be linearized (any result) or dropped.
    """
    ops: List[Operation] = list(history.ops)
    n = len(ops)
    if n == 0:
        return True
    completed = [o for o in ops if not o.pending]

    # precompute happens-before predecessor sets (indices into ops)
    preds: List[List[int]] = [[] for _ in ops]
    for i, a in enumerate(ops):
        for j, b in enumerate(ops):
            if i != j and history.happens_before(a, b):
                preds[j].append(i)

    completed_ids = frozenset(o.op_id for o in completed)
    id_to_idx = {o.op_id: i for i, o in enumerate(ops)}

    seen = set()
    nodes = [0]

    def dfs(linearized: frozenset, sm: StateMachine) -> bool:
        nodes[0] += 1
        if nodes[0] > max_nodes:
            raise RuntimeError("linearizability search budget exceeded")
        if completed_ids <= linearized:
            return True
        key = (linearized, _hashable(sm.snapshot()))
        if key in seen:
            return False
        seen.add(key)
        for i, op in enumerate(ops):
            if op.op_id in linearized:
                continue
            # all real-time predecessors must already be linearized
            if any(ops[p].op_id not in linearized for p in preds[i]):
                continue
            snap = sm.snapshot()
            result = sm.apply(op.op)
            ok = op.pending or result == op.result
            if ok and dfs(linearized | {op.op_id}, sm):
                return True
            sm.restore(snap)
            # also try *dropping* a pending op: handled implicitly - a pending
            # op that is never chosen simply stays out of `linearized`.
        return False

    return dfs(frozenset(), make_state_machine(sm_kind))


# ---------------------------------------------------------------------------
# Slot-stamped checks (scale to large histories)
# ---------------------------------------------------------------------------


def check_slot_order(history: History) -> List[str]:
    """If x <_H y then slot(x) <= slot(y); strict for write-write pairs.

    This is exactly the case analysis in the paper's section 3.5 proof.
    Returns a list of violation descriptions (empty = pass).
    """
    violations: List[str] = []
    done = [o for o in history.complete() if isinstance(o.result, object)]
    stamped = [o for o in done if _slot_of(o) is not None]
    for a in stamped:
        for b in stamped:
            if a is b or not history.happens_before(a, b):
                continue
            sa, sb = _slot_of(a), _slot_of(b)
            if sa > sb:
                violations.append(
                    f"{a.op} (slot {sa}) happens-before {b.op} (slot {sb})")
            elif sa == sb and not a.is_read and not b.is_read:
                violations.append(
                    f"write-write same slot {sa}: {a.op} <_H {b.op}")
    return violations


def _slot_of(op: Operation) -> Optional[int]:
    return op.slot


def check_register_reads(history: History) -> List[str]:
    """Register semantics with slot stamps: a read served at log position j
    must return the value of the latest write with slot <= j (unbatched
    histories only - batched writes share slots)."""
    violations: List[str] = []
    writes = sorted(
        ((op, _slot_of(op)) for op in history.complete()
         if not op.is_read and _slot_of(op) is not None),
        key=lambda t: t[1],
    )
    slots = [s for _, s in writes]
    if len(set(slots)) != len(slots):
        return ["duplicate write slots - use the exhaustive checker"]
    for op in history.complete():
        if not op.is_read or _slot_of(op) is None:
            continue
        j = _slot_of(op)
        latest = None
        for w, s in writes:
            if s <= j:
                latest = w
        expect = None if latest is None else latest.op[1]
        if op.result != expect:
            violations.append(
                f"read at slot {j} returned {op.result!r}, expected {expect!r}")
    return violations
