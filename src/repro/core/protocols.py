"""Protocol deployments: wiring roles into runnable clusters.

``CompartmentalizedMultiPaxos`` is the paper's full protocol (all six
compartmentalizations, each individually toggleable so the ablation study in
``benchmarks/ablation.py`` can walk the same path as paper Fig. 29).

``MultiPaxos`` is the vanilla baseline: 2f+1 colocated servers, the leader
broadcasts Phase 2 itself, majority quorums, f+1 replicas.

``UnreplicatedStateMachine`` is the paper's (non-fault-tolerant) upper bound.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .cluster import Network, Node
from .history import History
from .messages import ClientReply, ClientRequest, ReadReply
from .quorums import GridQuorums, MajorityQuorums, QuorumSystem
from .roles import Acceptor, Batcher, Client, Leader, ProxyLeader, Replica, Unbatcher
from .statemachine import StateMachine, make_state_machine


@dataclass
class DeploymentConfig:
    f: int = 1
    # compartmentalization 1: 0 proxies => vanilla self-broadcast leader
    n_proxy_leaders: int = 0
    # compartmentalization 2: grid quorums if set, else 2f+1 majorities
    grid: Optional[Tuple[int, int]] = None  # (rows, cols)
    # compartmentalization 3
    n_replicas: int = 0  # 0 => f+1
    # compartmentalizations 5/6
    n_batchers: int = 0
    n_unbatchers: int = 0
    batch_size: int = 10
    # reads: "linearizable" | "sequential" | "eventual"
    consistency: str = "linearizable"
    state_machine: str = "kv"
    seed: int = 0
    client_retries: bool = False
    # heartbeat-driven automatic leader failover (deterministic timers)
    auto_failover: bool = False
    # per-(src, dst) message delay, e.g. a GeoSpec's WAN matrix (timers
    # stay local; jitter stacks on top - see Network.send)
    latency_fn: Optional[Any] = None

    @property
    def n_acceptors(self) -> int:
        return self.grid[0] * self.grid[1] if self.grid else 2 * self.f + 1

    @property
    def effective_replicas(self) -> int:
        return self.n_replicas if self.n_replicas > 0 else self.f + 1


class BaseDeployment:
    """Common cluster-running helpers."""

    net: Network
    history: History
    clients: List[Any]

    def run_to_quiescence(self, max_steps: int = 2_000_000) -> int:
        return self.net.run(max_steps=max_steps)

    def all_done(self) -> bool:
        return all(c.done for c in self.clients)

    def results_of(self, client_index: int) -> List[Any]:
        return self.clients[client_index].results

    def total_messages(self) -> Dict[str, int]:
        """Total (sent + received) messages per role, keyed by the
        ``role/<i>`` address prefix - uniform across every deployment
        (MultiPaxos, Mencius, S-Paxos, CRAQ chains, unreplicated)."""
        out: Dict[str, int] = {}
        for addr, node in self.net.nodes.items():
            role = addr.split("/")[0]
            out[role] = out.get(role, 0) + node.msgs_received + node.msgs_sent
        return out


class CompartmentalizedMultiPaxos(BaseDeployment):
    """The paper's protocol; also the vanilla baseline via config toggles."""

    def __init__(self, cfg: DeploymentConfig, n_clients: int = 1,
                 network: Optional[Network] = None) -> None:
        self.cfg = cfg
        self.net = network or Network(seed=cfg.seed,
                                      latency_fn=cfg.latency_fn)
        self.history = History()

        f = cfg.f
        if cfg.grid is not None:
            rows, cols = cfg.grid
            assert rows >= f + 1 and cols >= f + 1, "grid must tolerate f"
            self.quorums: QuorumSystem = GridQuorums(rows=rows, cols=cols)
        else:
            self.quorums = MajorityQuorums(f=f)
        self.quorums.validate()

        self.acceptor_addrs = [f"acceptor/{i}" for i in range(self.quorums.n)]
        self.replica_addrs = [f"replica/{i}" for i in range(cfg.effective_replicas)]
        self.proxy_addrs = [f"proxy/{i}" for i in range(cfg.n_proxy_leaders)]
        self.batcher_addrs = [f"batcher/{i}" for i in range(cfg.n_batchers)]
        self.unbatcher_addrs = [f"unbatcher/{i}" for i in range(cfg.n_unbatchers)]
        self.leader_addrs = [f"leader/{i}" for i in range(f + 1)]

        # acceptors
        self.acceptors = [Acceptor(a, i) for i, a in enumerate(self.acceptor_addrs)]
        # replicas (each owns its own state machine copy)
        self.replicas = [
            Replica(addr, i, cfg.effective_replicas,
                    make_state_machine(cfg.state_machine),
                    unbatchers=self.unbatcher_addrs, seed=cfg.seed)
            for i, addr in enumerate(self.replica_addrs)
        ]
        # proxy leaders
        self.proxies = [
            ProxyLeader(addr, self.acceptor_addrs, self.quorums,
                        self.replica_addrs, seed=cfg.seed)
            for addr in self.proxy_addrs
        ]
        # leaders (f+1 proposers; leader 0 starts active)
        self.leaders = [
            Leader(addr, i, self.acceptor_addrs, self.quorums, self.proxy_addrs,
                   self.replica_addrs,
                   self_broadcast=(cfg.n_proxy_leaders == 0), seed=cfg.seed,
                   peers=self.leader_addrs, auto_failover=cfg.auto_failover)
            for i, addr in enumerate(self.leader_addrs)
        ]
        # batching plane
        self.batchers = [
            Batcher(addr, i, self.leader_addrs[0], cfg.batch_size,
                    acceptors=self.acceptor_addrs, quorums=self.quorums,
                    replicas=self.replica_addrs, seed=cfg.seed)
            for i, addr in enumerate(self.batcher_addrs)
        ]
        self.unbatchers = [Unbatcher(addr) for addr in self.unbatcher_addrs]
        # clients
        self.clients = [
            Client(f"client/{i}", i, self.leader_addrs[0], self.acceptor_addrs,
                   self.quorums, self.replica_addrs, batchers=self.batcher_addrs,
                   consistency=cfg.consistency, history=self.history,
                   seed=cfg.seed, retries=cfg.client_retries)
            for i in range(n_clients)
        ]

        for group in (self.acceptors, self.replicas, self.proxies, self.leaders,
                      self.batchers, self.unbatchers, self.clients):
            self.net.add_nodes(group)

        self.leaders[0].become_leader()
        if cfg.auto_failover:
            for l in self.leaders:
                l.start_failure_detector()
            # heartbeat timers never quiesce: settle phase 1 in a bounded
            # TIME window (drive such deployments with net.run(until=T))
            self.net.run(until=30)
        else:
            self.net.run(max_steps=10_000)  # settle phase 1
        assert self.leaders[0].active, "phase 1 must complete on a clean network"

    # -- convenience -------------------------------------------------------------
    @property
    def leader(self) -> Leader:
        for l in self.leaders:
            if l.active and l.addr not in self.net.crashed:
                return l
        return self.leaders[0]

    def fail_over(self, to_leader: int) -> None:
        """Crash the active leader, promote ``to_leader`` (phase 1 over a
        read quorum; adopted values re-proposed; holes filled with noops)."""
        for l in self.leaders:
            if l.active:
                self.net.crash(l.addr)
        self.leaders[to_leader].become_leader()


def vanilla_multipaxos(f: int = 1, n_clients: int = 1, seed: int = 0,
                       state_machine: str = "kv",
                       client_retries: bool = False) -> CompartmentalizedMultiPaxos:
    """Paper baseline: no proxies, majority quorums, f+1 replicas, no batching."""
    cfg = DeploymentConfig(f=f, n_proxy_leaders=0, grid=None, n_replicas=f + 1,
                           state_machine=state_machine, seed=seed,
                           client_retries=client_retries)
    return CompartmentalizedMultiPaxos(cfg, n_clients=n_clients)


def full_compartmentalized(f: int = 1, n_clients: int = 1, seed: int = 0,
                           n_proxy_leaders: int = 10,
                           grid: Tuple[int, int] = (2, 2),
                           n_replicas: int = 4,
                           n_batchers: int = 0, n_unbatchers: int = 0,
                           batch_size: int = 10,
                           consistency: str = "linearizable",
                           state_machine: str = "kv",
                           client_retries: bool = False) -> CompartmentalizedMultiPaxos:
    """The paper's evaluation deployment (section 8.1, unbatched by default)."""
    cfg = DeploymentConfig(f=f, n_proxy_leaders=n_proxy_leaders, grid=grid,
                           n_replicas=n_replicas, n_batchers=n_batchers,
                           n_unbatchers=n_unbatchers, batch_size=batch_size,
                           consistency=consistency, state_machine=state_machine,
                           seed=seed, client_retries=client_retries)
    return CompartmentalizedMultiPaxos(cfg, n_clients=n_clients)


# ---------------------------------------------------------------------------
# Unreplicated state machine (paper's upper bound; not fault tolerant)
# ---------------------------------------------------------------------------


class _UnreplicatedServer(Node):
    def __init__(self, addr: str, sm: StateMachine) -> None:
        super().__init__(addr)
        self.sm = sm
        self.executed = 0

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            result = self.sm.apply_checked(msg.command.op)
            self.executed += 1
            self.send(src, ClientReply(command_uid=msg.command.uid, result=result,
                                       slot=self.executed - 1))


class UnreplicatedStateMachine(BaseDeployment):
    def __init__(self, n_clients: int = 1, seed: int = 0,
                 state_machine: str = "kv",
                 latency_fn: Optional[Any] = None) -> None:
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()
        self.server = _UnreplicatedServer("server/0", make_state_machine(state_machine))
        self.net.add_node(self.server)
        self.clients = [
            Client(f"client/{i}", i, "server/0", [], MajorityQuorums(f=0), [],
                   history=self.history, seed=seed)
            for i in range(n_clients)
        ]
        self.net.add_nodes(self.clients)
