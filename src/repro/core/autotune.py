"""Budget-constrained deployment search ("How should a system be
compartmentalized?", paper section 9).

Given a machine budget M, a workload mix ``f_write`` and the calibrated
per-node rate ``alpha``, :func:`autotune` answers the question the paper's
authors answered by hand: *which* deployment - how many proxy leaders, what
acceptor grid, how many replicas, batchers, unbatchers - maximizes peak
throughput?  Two complementary engines:

* **Exhaustive**: enumerate the discrete config space under the budget via
  :mod:`repro.core.sweep` (one compiled batch, thousands of configs) and
  take the argmax, breaking ties toward fewer machines.

* **Greedy bottleneck-following** (:func:`bottleneck_trace`): start from
  the minimal decoupled deployment and repeatedly scale whatever station is
  currently saturating - exactly the procedure behind the paper's Fig. 29
  ablation staircase.  The returned trace *is* the bottleneck-migration
  narrative: at every step it names the saturating station, the knob turned,
  and the resulting peak.

The greedy trace explains the optimum; the exhaustive search certifies it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .analytical import DeploymentModel, multipaxos_model
from .api import (
    STATION_INDEX,
    AutoscalePolicy,
    ShardingSpec,
    Workload,
    resolve_workload,
    variant_spec,
)
from .sweep import (
    CompiledSweep,
    Config,
    SweepSpec,
    compile_models,
    compile_sweep,
    config_variant,
    model_for,
)
from .transient import Event


@dataclass(frozen=True)
class TraceStep:
    """One rung of the bottleneck-migration staircase."""

    step: int
    label: str                 # the knob turned to get here
    config: Optional[Config]   # None for the vanilla MultiPaxos baseline
    machines: int
    peak: float                # cmds/s at this rung
    bottleneck: str            # station saturating at this rung


@dataclass(frozen=True)
class AutotuneResult:
    best_config: Config
    best_model: DeploymentModel
    best_peak: float
    best_bottleneck: str
    machines: int              # servers used by the best deployment
    budget: int
    n_candidates: int          # feasible configs enumerated
    trace: Tuple[TraceStep, ...]  # greedy bottleneck-migration staircase
    objective: str = "peak"    # what "best" ranked by
    best_p99: Optional[float] = None  # seed-mean p99 s (fault objectives)


@dataclass(frozen=True)
class VariantChoice:
    """Best deployment of one protocol variant under the budget."""

    variant: str
    config: Config
    model: DeploymentModel
    peak: float                # cmds/s (bottleneck law)
    machines: int
    bottleneck: str


@dataclass(frozen=True)
class VariantAutotuneResult:
    """Cross-variant budget search: which protocol wins at budget B?"""

    winner: VariantChoice
    per_variant: Dict[str, VariantChoice]  # best of each variant
    budget: int
    n_candidates: int          # feasible configs across all variants


def candidate_spec(budget: int, f: int = 1, batching: bool = False,
                   batch_sizes: Tuple[int, ...] = (10, 50, 100)) -> SweepSpec:
    """The discrete config space under a machine budget.

    Grids keep write quorums (columns) of at least ``f + 1`` members so f
    failures are survivable; the ``(2f+1, 1)`` column is the
    majority-quorum degenerate case the ablation starts from.  Knob ranges
    are clipped so the *smallest* other components still fit: anything
    larger can never be feasible and would only bloat the batch.  The
    unbatched clipping is the compartmentalized variant's registered
    ``candidate_knobs`` - one source of truth shared with
    :func:`autotune_variants`.
    """
    knobs = variant_spec("compartmentalized").candidate_knobs(budget, f)
    max_proxies = knobs["n_proxy_leaders"][-1]
    max_replicas = knobs["n_replicas"][-1]
    if not batching:
        return SweepSpec(
            f=f,
            n_proxy_leaders=knobs["n_proxy_leaders"],
            grids=knobs["grids"],
            n_replicas=knobs["n_replicas"],
        )
    # batched spec: batchers/unbatchers dominate, everything else is cheap
    # per-batch - coarsen the other knobs to keep the product tractable
    min_rest = 1 + (f + 1) + (f + 1)       # leader + smallest grid + replicas
    max_bu = max(budget - min_rest - 1, 1)
    return SweepSpec(
        f=f,
        n_proxy_leaders=tuple(range(1, min(max_proxies, 4) + 1)),
        grids=((2 * f + 1, 1), (f + 1, f + 1)),
        n_replicas=tuple(range(f + 1, min(max_replicas, f + 3) + 1)),
        batch_sizes=batch_sizes,
        n_batchers=tuple(range(1, min(max_bu, 12) + 1)),
        n_unbatchers=tuple(range(1, min(max_bu, 12) + 1)),
    )


def _eval(config: Config, alpha: float, workload: Workload
          ) -> Tuple[float, str, int, float]:
    """(peak, bottleneck, machines, total demand).  Total demand is the
    plateau tie-breaker: a move that keeps the peak flat but lowers the
    summed demand (e.g. +1 batcher shifting the bottleneck to the
    unbatcher) is still progress toward the next rung."""
    m = model_for(config, workload)
    bn, _ = m.bottleneck(workload)
    total = sum(m.demands(workload).values())
    return m.peak_throughput(alpha, workload), bn, m.total_machines(), total


# knob-turn candidates per bottleneck station: (label, config transform)
def _moves(config: Config, batching: bool) -> Dict[str, List[Tuple[str, Config]]]:
    r, w = config["grid_rows"], config["grid_cols"]
    moves: Dict[str, List[Tuple[str, Config]]] = {
        "proxy": [("+1 proxy leader",
                   {**config, "n_proxy_leaders": config["n_proxy_leaders"] + 1})],
        "replica": [("+1 replica",
                     {**config, "n_replicas": config["n_replicas"] + 1})],
        "acceptor": [
            ("+1 grid column (write sharding)", {**config, "grid_cols": w + 1}),
            ("+1 grid row (read sharding)", {**config, "grid_rows": r + 1}),
        ],
        "batcher": [], "unbatcher": [], "leader": [],
    }
    if batching:
        if config["n_batchers"] == 0:
            on = {**config, "n_batchers": 1, "n_unbatchers": 1,
                  "batch_size": 100}
            moves["leader"] = [("enable batching (1 batcher, 1 unbatcher)", on)]
        else:
            moves["batcher"] = [("+1 batcher",
                                 {**config, "n_batchers": config["n_batchers"] + 1})]
            moves["unbatcher"] = [("+1 unbatcher",
                                   {**config,
                                    "n_unbatchers": config["n_unbatchers"] + 1})]
    return moves


def bottleneck_trace(budget: int, alpha: float,
                     workload: Optional[Union[Workload, float]] = None,
                     f_write: Optional[float] = None,
                     f: int = 1, batching: bool = False,
                     max_steps: int = 64) -> List[TraceStep]:
    """Greedy bottleneck-following from vanilla MultiPaxos up to the budget.

    Step 0 is the un-decoupled baseline; step 1 decouples into the minimal
    compartmentalized deployment; every further step scales the currently
    saturating station (trying each applicable knob, keeping the best that
    fits the budget).  Stops when the bottleneck has no scaling knob left
    (the sequencing leader, in unbatched mode) or no move improves.
    """
    w = resolve_workload(workload, f_write, where="bottleneck_trace")
    mp = multipaxos_model(f=f)
    trace: List[TraceStep] = [TraceStep(
        step=0, label="vanilla MultiPaxos", config=None,
        machines=mp.total_machines(),
        peak=mp.peak_throughput(alpha, w),
        bottleneck=mp.bottleneck(w)[0])]

    # paper Fig. 29a step 1: decouple into 2 proxies, 2f+1 acceptors, f+1
    # replicas (1 proxy would *lose* throughput vs the fused leader)
    config: Config = dict(f=f, n_proxy_leaders=2, grid_rows=2 * f + 1,
                          grid_cols=1, n_replicas=f + 1, batch_size=1,
                          n_batchers=0, n_unbatchers=0)
    peak, bn, machines, total = _eval(config, alpha, w)
    if machines > budget:
        return trace
    trace.append(TraceStep(step=1, label="decouple (2 proxy leaders)",
                           config=dict(config), machines=machines, peak=peak,
                           bottleneck=bn))

    seen = {tuple(sorted(config.items()))}
    for step in range(2, max_steps):
        best: Optional[Tuple[float, float, str, Config, str, int]] = None
        for label, cand in _moves(config, batching)[bn]:
            key = tuple(sorted(cand.items()))
            if key in seen:
                continue
            p, b, m, tot = _eval(cand, alpha, w)
            if m > budget:
                continue
            if best is None or (p, -tot) > (best[0], -best[1]):
                best = (p, tot, b, cand, label, m)
        # take the move if it raises the peak, or keeps it flat while
        # freeing headroom (bottleneck migrates within a plateau)
        if best is None or best[0] < peak * (1 - 1e-9):
            break
        if best[0] <= peak * (1 + 1e-9) and best[1] >= total * (1 - 1e-9):
            break
        peak, total, bn, config, label, machines = best
        seen.add(tuple(sorted(config.items())))
        trace.append(TraceStep(step=step, label=label, config=dict(config),
                               machines=machines, peak=peak, bottleneck=bn))
    return trace


def autotune(budget: int, alpha: float,
             workload: Optional[Union[Workload, float]] = None,
             f_write: Optional[float] = None, f: int = 1,
             batching: bool = False,
             compiled: Optional[CompiledSweep] = None,
             objective: str = "peak",
             fault_events: Optional[List[Event]] = None,
             shortlist: int = 16,
             transient_kwargs: Optional[Dict] = None) -> AutotuneResult:
    """Best deployment for a machine budget, plus the greedy
    bottleneck-migration trace that explains it.

    ``workload`` is the evaluation point (write mix, skew, arrival and
    batch-fill hints - one :class:`~repro.core.api.Workload` value; the
    legacy ``f_write=`` scalar still works behind a deprecation shim).

    ``objective`` selects the figure of merit:

    * ``"peak"`` (default) - steady-state bottleneck-law throughput;
    * ``"p99_under_failover"`` - tail latency under faults: the top
      ``shortlist`` feasible configs by peak are re-ranked by seed-mean
      p99 latency from the batched transient engine running
      ``fault_events`` (default: leader crash over the middle of the run)
      - deployments that merely tie on steady-state mean separate here by
      how deep and long their failover stall is.

    ``compiled`` lets callers reuse an already-compiled candidate space
    (e.g. to autotune many workload mixes against one batch)."""
    w = resolve_workload(workload, f_write, where="autotune")
    # smallest deployment the candidate space contains: leader + 1 proxy +
    # the (f+1, 1) column grid + f+1 replicas
    if budget < 1 + 1 + (f + 1) + (f + 1):
        raise ValueError(
            f"budget {budget} cannot hold leader + 1 proxy + {(f+1)}x1 "
            f"grid + {f+1} replicas for f={f}")
    if compiled is None:
        compiled = compile_sweep(candidate_spec(budget, f=f, batching=batching))
    if compiled.configs is None:
        raise ValueError(
            "compiled sweep carries no configs - build it with compile_sweep "
            "(or pass configs to compile_models)")
    feasible = compiled.machines <= budget
    if not feasible.any():
        raise ValueError(
            f"no candidate in the compiled sweep fits budget {budget} "
            f"(smallest uses {int(compiled.machines.min())} machines)")
    peaks = np.where(feasible, compiled.peak_throughput(alpha, w),
                     -np.inf)
    # argmax; ties break toward fewer machines
    order = np.lexsort((compiled.machines, -peaks))
    best_p99: Optional[float] = None
    if objective == "peak":
        best_i = int(order[0])
    elif objective == "p99_under_failover":
        # re-rank the peak shortlist by tail latency under the fault script
        # (one batched transient call over shortlist x seeds lanes)
        short = [int(i) for i in order[:shortlist] if np.isfinite(peaks[i])]
        sub = compiled.subset(short)
        events = fault_events or [Event("leader", 0.4, 0.6, 1e9)]
        res = sub.transient(alpha, workload=w, events=events,
                            **(transient_kwargs or {}))
        p99 = res.seed_mean_p99()
        pick = int(np.lexsort((sub.machines, p99))[0])
        best_i = short[pick]
        best_p99 = float(p99[pick])
    else:
        raise ValueError(f"unknown objective {objective!r}")
    best_config = dict(compiled.configs[best_i])
    # report the workload-*adapted* model (when the workload reshapes
    # demands, the compiled row's peak came from it - the unadapted model
    # would name a different bottleneck and disagree with best_peak)
    best_model = (model_for(best_config, w) if w.adapts_demands
                  else compiled.models[best_i])
    best_peak = float(peaks[best_i])
    best_bn = best_model.bottleneck(w)[0]
    machines = int(compiled.machines[best_i])

    trace = tuple(bottleneck_trace(budget, alpha, workload=w, f=f,
                                   batching=batching))
    # the greedy climber can escape a coarsened exhaustive grid (it has no
    # cartesian-product blowup to worry about) - keep whichever won.  Only
    # meaningful when peak is the objective being maximized.
    last = trace[-1]
    if objective == "peak" and last.config is not None \
            and last.peak > best_peak:
        best_config = dict(last.config)
        best_model = model_for(best_config, w)
        best_peak, best_bn, machines = (last.peak, last.bottleneck,
                                        last.machines)
    return AutotuneResult(
        best_config=best_config,
        best_model=best_model,
        best_peak=best_peak,
        best_bottleneck=best_bn,
        machines=machines,
        budget=budget,
        n_candidates=int(feasible.sum()),
        trace=trace,
        objective=objective,
        best_p99=best_p99,
    )


# ---------------------------------------------------------------------------
# Cross-variant search: which protocol wins at budget B?
# ---------------------------------------------------------------------------


def _meets_floors(model: DeploymentModel,
                  policy: Optional[AutoscalePolicy]) -> bool:
    """True when every station the deployment actually provisions sits
    at or above the policy's pinned per-station floor.  Stations the
    variant does not have (zero servers) are exempt - a floor on
    ``proxy`` cannot disqualify a chain protocol."""
    if policy is None or not policy.min_counts:
        return True
    srv = model.demand_slots()[2]
    for station, lo in policy.min_counts:
        col = STATION_INDEX.get(station)
        if col is None or col >= len(srv):
            continue
        if 0 < srv[col] < lo:
            return False
    return True


def variant_candidate_configs(budget: int, f: int = 1,
                              variants: Tuple[str, ...] = (
                                  "compartmentalized", "mencius", "spaxos"),
                              policy: Optional[AutoscalePolicy] = None,
                              ) -> List[Config]:
    """The per-variant discrete config spaces under one machine budget.

    One generic loop over the variant registry: each
    :class:`~repro.core.api.VariantSpec` that declares ``candidate_knobs``
    contributes its budget-clipped knob product (compartmentalized
    MultiPaxos gets the full :func:`candidate_spec` space; Mencius and
    S-Paxos declare coarsened grids - their extra axes would otherwise
    blow up the cartesian product); variants without one contribute their
    default knob product (a single config for the knobless baselines).
    Over-budget combinations are kept (the batched eval masks them by
    ``machines``) so one compiled space serves nearby budgets too.
    Runtime-registered variants ride this search with no edits here.

    An :class:`~repro.core.api.AutoscalePolicy` with pinned
    ``min_counts`` prunes configs provisioned *below* a floor up front:
    the autotuner's fewer-machines tie-break would otherwise hand the
    elastic controller a starting point it could never legally reach by
    draining (floors bind drains, so they must bind the search too)."""
    configs: List[Config] = []
    for variant in variants:
        spec = variant_spec(variant)
        overrides = (spec.candidate_knobs(budget, f)
                     if spec.candidate_knobs is not None else {})
        configs.extend(spec.configs(f=f, overrides=overrides))
    if policy is not None and policy.min_counts:
        configs = [c for c in configs if _meets_floors(model_for(c), policy)]
    return configs


def autotune_variants(budget: int, alpha: float,
                      workload: Optional[Union[Workload, float]] = None,
                      f_write: Optional[float] = None,
                      f: int = 1,
                      variants: Tuple[str, ...] = (
                          "compartmentalized", "mencius", "spaxos"),
                      compiled: Optional[CompiledSweep] = None,
                      policy: Optional[AutoscalePolicy] = None,
                      ) -> VariantAutotuneResult:
    """Search across protocol variants under one machine budget.

    Lowers every variant's candidate space into ONE compiled demand tensor
    (heterogeneous station sets pad into the canonical slots), evaluates
    the whole mixed batch with the vectorized bottleneck law at one
    :class:`~repro.core.api.Workload`, and reports the best deployment of
    each variant plus the overall winner - the paper's "a technique, not
    a protocol" claim as a search result.  Ties break toward fewer
    machines, like :func:`autotune` - unless an autoscale ``policy``
    pins per-station ``min_counts``, in which case deployments below a
    floor are infeasible however few machines they use (the controller
    could never drain back up to legality)."""
    w = resolve_workload(workload, f_write, where="autotune_variants")
    if compiled is None:
        configs = variant_candidate_configs(budget, f=f, variants=variants,
                                            policy=policy)
        compiled = compile_models([model_for(c) for c in configs], configs)
    if compiled.configs is None:
        raise ValueError(
            "compiled sweep carries no configs - build it with compile_sweep "
            "(or pass configs to compile_models)")
    feasible = compiled.machines <= budget
    if policy is not None and policy.min_counts:
        floors_ok = np.asarray([_meets_floors(m, policy)
                                for m in compiled.models])
        feasible = feasible & floors_ok
    peaks = np.where(feasible, compiled.peak_throughput(alpha, w),
                     -np.inf)
    order = np.lexsort((compiled.machines, -peaks))
    per_variant: Dict[str, VariantChoice] = {}
    for i in order:
        i = int(i)
        if not np.isfinite(peaks[i]) or peaks[i] <= 0:
            break  # sorted: everything after is infeasible too
        v = config_variant(compiled.configs[i])
        if v not in per_variant:
            # workload-adapted model: consistent with the peak the row
            # was ranked by (skew/batch-fill reshape the demand table)
            m = (model_for(compiled.configs[i], w) if w.adapts_demands
                 else compiled.models[i])
            per_variant[v] = VariantChoice(
                variant=v, config=dict(compiled.configs[i]), model=m,
                peak=float(peaks[i]), machines=int(compiled.machines[i]),
                bottleneck=m.bottleneck(w)[0])
    if not per_variant:
        # name each variant's smallest deployment so the caller can see
        # how far off the budget is, per protocol
        mins: Dict[str, int] = {}
        for i, cfg in enumerate(compiled.configs):
            v = config_variant(cfg)
            m = int(compiled.machines[i])
            mins[v] = min(mins.get(v, m), m)
        detail = ", ".join(f"{v} needs >= {m}" for v, m in sorted(mins.items()))
        raise ValueError(
            f"no candidate of any variant fits budget {budget} "
            f"(per-variant minimum machines: {detail})")
    winner = max(per_variant.values(), key=lambda c: (c.peak, -c.machines))
    return VariantAutotuneResult(winner=winner, per_variant=per_variant,
                                 budget=budget,
                                 n_candidates=int(feasible.sum()))


# ---------------------------------------------------------------------------
# Policy search: which autoscale policy saves the most machine-hours?
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PolicyChoice:
    """One policy's scorecard on the load schedule (``policy`` None is
    the frozen static baseline)."""

    policy: Optional[AutoscalePolicy]
    trace: "object"            # AutoscaleTrace (full evidence)
    machine_time: float        # machine x run-fraction integral
    peak_p99: float            # worst-window p99, seconds
    peak_machines: int


@dataclass(frozen=True)
class PolicyAutotuneResult:
    """Verdict of :func:`autotune_policy`: the cheapest policy whose
    worst-window p99 stays within ``p99_slack`` of the static baseline."""

    winner: PolicyChoice
    static: PolicyChoice
    choices: Tuple[PolicyChoice, ...]
    p99_slack: float

    def describe(self) -> str:
        saved = 1.0 - self.winner.machine_time / self.static.machine_time
        pol = (self.winner.policy.describe() if self.winner.policy
               else "static")
        return (f"winner {pol}: machine_time "
                f"{self.winner.machine_time:.2f} vs static "
                f"{self.static.machine_time:.2f} ({saved:.0%} saved), "
                f"peak p99 {self.winner.peak_p99:.3e}s vs "
                f"{self.static.peak_p99:.3e}s "
                f"(slack {self.p99_slack:.2f})")


def autotune_policy(policies: Tuple[AutoscalePolicy, ...],
                    base: np.ndarray, servers: np.ndarray,
                    load: np.ndarray, *,
                    p99_slack: float = 1.10,
                    budget: Optional[int] = None,
                    **kwargs) -> PolicyAutotuneResult:
    """Search an :class:`~repro.core.api.AutoscalePolicy` grid on one
    deployment and load schedule: every policy (plus the frozen static
    baseline) becomes one lane of a single
    :func:`repro.core.autoscale.autoscale_grid` run - shared probes, one
    batched full-horizon replay - and the winner is the policy with the
    smallest machine-time integral whose worst-window p99 stays within
    ``p99_slack`` x the static baseline's (and whose peak provisioning
    fits ``budget``, when given).  The same feasibility-mask +
    ``lexsort`` idiom as the budget autotuners; if no policy qualifies,
    the static baseline wins."""
    from .autoscale import autoscale_grid
    if not policies:
        raise ValueError("autotune_policy needs at least one policy")
    if p99_slack <= 0.0:
        raise ValueError(f"p99_slack must be positive: {p99_slack}")
    lanes: List[Optional[AutoscalePolicy]] = list(policies) + [None]
    base = np.asarray(base, dtype=np.float64)
    servers = np.asarray(servers)
    bases = np.repeat(base[None, :], len(lanes), axis=0)
    srv = np.repeat(servers[None, :], len(lanes), axis=0)
    traces = autoscale_grid(bases, srv, lanes, load, **kwargs)
    choices = tuple(PolicyChoice(
        policy=t.policy, trace=t, machine_time=t.machine_time,
        peak_p99=t.peak_p99(), peak_machines=t.peak_machines)
        for t in traces)
    static = choices[-1]
    cap = p99_slack * static.peak_p99
    pool = [c for c in choices[:-1]
            if c.peak_p99 <= cap
            and (budget is None or c.peak_machines <= budget)]
    if not pool:
        winner = static
    else:
        mt = np.asarray([c.machine_time for c in pool])
        p9 = np.asarray([c.peak_p99 for c in pool])
        winner = pool[int(np.lexsort((p9, mt))[0])]
    return PolicyAutotuneResult(winner=winner, static=static,
                                choices=choices, p99_slack=p99_slack)


# ---------------------------------------------------------------------------
# Sharded search: split one machine budget across shard groups
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardChoice:
    """One shard group's slice of a sharded budget split."""

    shard: int
    weight: float              # traffic fraction routed to this shard
    budget: int                # machines allocated by the split
    machines: int              # machines the chosen config actually uses
    config: Config
    peak: float                # shard-local peak, cmds/s
    effective: float           # peak / weight: system cap if this binds


@dataclass(frozen=True)
class ShardedAutotuneResult:
    """A machine budget split across shards, each shard autotuned.

    ``total_peak = min_s peak_s / w_s``: the system saturates when the
    worst-provisioned shard can no longer keep up with its traffic
    share.  Under skew the split is *asymmetric* - the hot shard buys
    more machines per unit of budget."""

    sharding: "ShardingSpec"
    budget: int
    weights: Tuple[float, ...]
    shards: Tuple[ShardChoice, ...]
    total_peak: float          # cmds/s across the whole sharded system
    bottleneck_shard: int      # the shard binding total_peak
    machines: int              # sum of machines actually used
    n_candidates: int          # candidate configs in the per-shard space


def autotune_sharded(budget: int, alpha: float, sharding: "ShardingSpec",
                     workload: Optional[Union[Workload, float]] = None,
                     f_write: Optional[float] = None, f: int = 1,
                     compiled: Optional[CompiledSweep] = None,
                     ) -> ShardedAutotuneResult:
    """Split a machine budget across ``sharding.n_shards`` groups and pick
    each group's best deployment.

    The compiled candidate space is shared by all shards (one batched
    bottleneck-law evaluation); a lookup table maps every per-shard
    budget ``b`` to the best peak any config achieves with ``<= b``
    machines.  A greedy water-filling loop then grants machines one at a
    time to whichever shard currently binds
    ``total = min_s peak_s / w_s`` - so under key skew the hot shard
    (larger ``w_s``) ends up with a bigger, different config than the
    cold shards, which is exactly why the split is searched rather than
    divided evenly."""
    w = resolve_workload(workload, f_write, where="autotune_sharded")
    s = sharding.n_shards
    weights = np.asarray(sharding.resolved_weights(w), dtype=np.float64)
    min_b = 1 + 1 + (f + 1) + (f + 1)
    if budget < s * min_b:
        raise ValueError(
            f"budget {budget} cannot hold {s} shards x {min_b} machines "
            f"(leader + 1 proxy + ({f+1})x1 grid + {f+1} replicas each)")
    max_b = budget - (s - 1) * min_b
    if compiled is None:
        compiled = compile_sweep(candidate_spec(max_b, f=f))
    if compiled.configs is None:
        raise ValueError(
            "compiled sweep carries no configs - build it with compile_sweep")
    peaks = compiled.peak_throughput(alpha, w)
    machines = compiled.machines.astype(np.int64)

    # best config for every per-shard budget: exact at-cost table, then a
    # prefix max so best_idx[b] is the best config using <= b machines
    # (ties break toward fewer machines via the >= prefix update)
    best_peak = np.full(max_b + 1, -np.inf)
    best_idx = np.full(max_b + 1, -1, dtype=np.int64)
    for i, b in enumerate(machines):
        if b <= max_b and peaks[i] > best_peak[b]:
            best_peak[b] = peaks[i]
            best_idx[b] = i
    for b in range(1, max_b + 1):
        if best_peak[b - 1] >= best_peak[b]:
            best_peak[b] = best_peak[b - 1]
            best_idx[b] = best_idx[b - 1]
    if best_idx[min_b] < 0:
        raise ValueError(
            f"no candidate config fits the per-shard floor of {min_b} "
            f"machines (smallest uses {int(machines.min())})")

    # water-fill: every machine goes to the shard binding the system cap
    budgets = np.full(s, min_b, dtype=np.int64)
    while int(budgets.sum()) < budget:
        with np.errstate(divide="ignore"):
            eff = np.where(weights > 0, best_peak[budgets] / weights, np.inf)
        # ties (uniform weights) break toward the least-provisioned shard,
        # so symmetric traffic gets a symmetric split
        budgets[int(np.lexsort((budgets, eff))[0])] += 1

    shards = []
    for i in range(s):
        idx = int(best_idx[budgets[i]])
        peak_i = float(best_peak[budgets[i]])
        eff = peak_i / weights[i] if weights[i] > 0 else np.inf
        shards.append(ShardChoice(
            shard=i, weight=float(weights[i]), budget=int(budgets[i]),
            machines=int(machines[idx]), config=dict(compiled.configs[idx]),
            peak=peak_i, effective=float(eff)))
    effective = np.array([c.effective for c in shards])
    bottleneck = int(np.argmin(effective))
    return ShardedAutotuneResult(
        sharding=sharding,
        budget=budget,
        weights=tuple(float(x) for x in weights),
        shards=tuple(shards),
        total_peak=float(effective[bottleneck]),
        bottleneck_shard=bottleneck,
        machines=sum(c.machines for c in shards),
        n_candidates=len(compiled),
    )


# ---------------------------------------------------------------------------
# placement search (geo plane)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlacementChoice:
    """Best deployment under one placement of stations onto regions."""

    placement: str             # candidate name: "spread", "single/<r>", ...
    geo: "GeoSpec"             # the GeoSpec carrying that placement
    config: Config
    index: int                 # row in the compiled candidate sweep
    machines: int
    worst_p99: float           # max p99 over client-bearing regions
    blended_p99: float         # client-weighted mean p99
    region_p50: Tuple[float, ...]
    region_p99: Tuple[float, ...]
    peak: float                # bottleneck-law peak (cmds/s)


@dataclass(frozen=True)
class PlacementAutotuneResult:
    """Which placement (and which config under it) wins at budget B?

    ``single_region_best`` is the best fully-pinned candidate - the
    baseline a geo-aware placement has to beat for spread clients."""

    best: PlacementChoice
    per_placement: Dict[str, PlacementChoice]
    single_region_best: Optional[PlacementChoice]
    budget: int
    n_candidates: int          # feasible configs per placement
    regions: Tuple[str, ...]


def autotune_placement(budget: int, alpha: float, geo: "GeoSpec",
                       workload: Optional[Union[Workload, float]] = None,
                       f_write: Optional[float] = None, f: int = 1,
                       variant: str = "compartmentalized",
                       n_clients: int = 64,
                       compiled: Optional[CompiledSweep] = None,
                       ) -> PlacementAutotuneResult:
    """Search station placements under a machine budget, ranking by the
    *worst client-bearing region's* blended p99 latency.

    The candidate family (:func:`repro.core.geo.placement_candidates`) is
    ``spread`` (round-robin), ``single/<region>`` (everything pinned) and
    ``hub/<region>`` (ordering core pinned, replica tier spread).  For
    each placement one :meth:`CompiledSweep.geo_latency` call scores every
    config x region at once; the per-placement winner minimizes worst-
    region p99, breaking ties toward blended p99 and then fewer machines.
    The throughput-shaped knobs (how many proxies, grid shape) and the
    latency-shaped placement compose: the same compiled candidate space
    serves both axes.  Batched candidates are dropped (no WAN lowering).

    The search first canonicalizes the region labeling (sorted by region
    name, via :meth:`GeoSpec.relabeled`), so the result is invariant
    under region relabeling: the default round-robin cycles behind the
    ``spread`` / ``hub`` candidates walk the regions tuple in order, and
    without canonicalization two labelings of the same physical WAN
    would score physically different deployments.  Results are keyed by
    region *name* throughout, so callers never see the canonical frame.
    """
    from .geo import placement_candidates
    w = resolve_workload(workload, f_write, where="autotune_placement")
    canon = tuple(sorted(range(geo.n_regions), key=lambda i: geo.regions[i]))
    geo = geo.relabeled(canon)
    if compiled is None:
        configs = [c for c in variant_candidate_configs(budget, f, (variant,))
                   if not c.get("n_batchers") and not c.get("n_unbatchers")]
        compiled = compile_models([model_for(c) for c in configs], configs)
    if compiled.configs is None:
        raise ValueError(
            "autotune_placement needs a config-bearing sweep; compile with "
            "compile_sweep(spec) rather than compile_models(models)")
    feasible = compiled.machines <= budget
    if not feasible.any():
        raise ValueError(
            f"no placement candidate fits in budget={budget} "
            f"(smallest candidate uses {int(compiled.machines.min())})")
    peaks = compiled.peak_throughput(alpha, w)
    per: Dict[str, PlacementChoice] = {}
    for name, placed in placement_candidates(variant, geo).items():
        surf = compiled.geo_latency(alpha, placed, workload=w,
                                    n_clients=n_clients)
        worst = surf.worst_p99()
        blend = surf.blended_p99()
        score = np.where(feasible, worst, np.inf)
        i = int(np.lexsort((compiled.machines, blend, score))[0])
        per[name] = PlacementChoice(
            placement=name, geo=placed, config=dict(compiled.configs[i]),
            index=i, machines=int(compiled.machines[i]),
            worst_p99=float(worst[i]), blended_p99=float(blend[i]),
            region_p50=tuple(float(x) for x in surf.p50[i]),
            region_p99=tuple(float(x) for x in surf.p99[i]),
            peak=float(peaks[i]))

    def rank(c: PlacementChoice) -> Tuple[float, float, int]:
        return (c.worst_p99, c.blended_p99, c.machines)

    best = min(per.values(), key=rank)
    singles = [c for n, c in per.items() if n.startswith("single/")]
    single_best = min(singles, key=rank) if singles else None
    return PlacementAutotuneResult(
        best=best, per_placement=per, single_region_best=single_best,
        budget=budget, n_candidates=int(feasible.sum()),
        regions=tuple(geo.regions))
