"""Compartmentalized state machine replication - the paper's contribution.

Correctness plane (deterministic, message-level):
  protocols.CompartmentalizedMultiPaxos / vanilla_multipaxos /
  UnreplicatedStateMachine, mencius.MenciusDeployment,
  spaxos.SPaxosDeployment, craq.CraqDeployment
  + linearizability checkers.

Performance plane (JAX, calibrated on the paper's anchors):
  analytical.* demand tables + bottleneck law, simulator.mva_curve /
  fluid_throughput / des_throughput.
"""
from .analytical import (
    DeploymentModel,
    Station,
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
    craq_model,
    mixed_workload_speedup,
    multipaxos_model,
    read_scalability_law,
    unreplicated_model,
)
from .cluster import Network, Node
from .craq import CraqDeployment
from .history import History, Operation
from .linearizability import (
    check_linearizable,
    check_register_reads,
    check_slot_order,
)
from .mencius import MenciusDeployment
from .messages import Command, noop_command
from .protocols import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
    UnreplicatedStateMachine,
    full_compartmentalized,
    vanilla_multipaxos,
)
from .quorums import GridQuorums, MajorityQuorums
from .simulator import des_throughput, fluid_throughput, mva_curve, mva_curves_batch
from .spaxos import SPaxosDeployment
from .statemachine import AppendLog, KVStore, Register, make_state_machine

__all__ = [
    "AppendLog", "Command", "CompartmentalizedMultiPaxos", "CraqDeployment",
    "DeploymentConfig", "DeploymentModel", "GridQuorums", "History", "KVStore",
    "MajorityQuorums", "MenciusDeployment", "Network", "Node", "Operation",
    "Register", "SPaxosDeployment", "Station", "UnreplicatedStateMachine",
    "ablation_steps", "calibrate_alpha", "check_linearizable",
    "check_register_reads", "check_slot_order", "compartmentalized_model",
    "craq_model", "des_throughput", "fluid_throughput", "full_compartmentalized",
    "make_state_machine", "mixed_workload_speedup", "multipaxos_model",
    "mva_curve", "mva_curves_batch", "noop_command", "read_scalability_law",
    "unreplicated_model", "vanilla_multipaxos",
]
