"""Compartmentalized state machine replication - the paper's contribution.

Correctness plane (deterministic, message-level):
  protocols.CompartmentalizedMultiPaxos / vanilla_multipaxos /
  UnreplicatedStateMachine, mencius.MenciusDeployment,
  spaxos.SPaxosDeployment, craq.CraqDeployment
  + linearizability checkers.

Performance plane (JAX, calibrated on the paper's anchors):
  analytical.* demand tables + bottleneck law for every protocol variant
  (MultiPaxos, Mencius, S-Paxos, CRAQ, unreplicated - the VARIANT_MODELS
  registry), simulator.mva_curve / fluid_throughput / des_throughput,
  transient.* scripted dynamics, sweep.* batched mixed-variant surfaces,
  autotune.* budget search (autotune_variants across protocols).
"""
from .analytical import (
    STATION_ORDER,
    VARIANT_MODELS,
    DeploymentModel,
    Station,
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
    craq_chain_model,
    craq_model,
    craq_station_demands,
    mencius_model,
    mixed_workload_speedup,
    multipaxos_model,
    read_scalability_law,
    spaxos_model,
    stack_demands,
    unreplicated_model,
    vanilla_mencius_model,
    vanilla_spaxos_model,
)
from .autotune import (
    AutotuneResult,
    TraceStep,
    VariantAutotuneResult,
    VariantChoice,
    autotune,
    autotune_variants,
    bottleneck_trace,
    variant_candidate_configs,
)
from .cluster import Network, Node
from .craq import CraqDeployment
from .history import History, Operation
from .linearizability import (
    check_linearizable,
    check_register_reads,
    check_slot_order,
)
from .mencius import MenciusDeployment
from .messages import Command, noop_command
from .protocols import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
    UnreplicatedStateMachine,
    full_compartmentalized,
    vanilla_multipaxos,
)
from .quorums import GridQuorums, MajorityQuorums
from .simulator import (
    des_throughput,
    fluid_throughput,
    fluid_throughput_batch,
    mva_curve,
    mva_curves_batch,
    mva_curves_from_demands,
)
from .spaxos import SPaxosDeployment
from .sweep import (
    CompiledSweep,
    SweepSpec,
    compile_models,
    compile_sweep,
    config_variant,
    model_for,
)
from .transient import (
    CRASH,
    Event,
    TransientResult,
    build_schedule,
    failover_schedule,
    mencius_skip_storm_schedule,
    scale_schedule,
    schedule_from_demands,
    simulate_transient,
    spaxos_payload_ramp_schedule,
    transient_throughput,
)
from .statemachine import AppendLog, KVStore, Register, make_state_machine

__all__ = [
    "AppendLog", "AutotuneResult", "CRASH", "Command",
    "CompartmentalizedMultiPaxos", "CompiledSweep", "CraqDeployment",
    "DeploymentConfig", "DeploymentModel", "Event", "GridQuorums", "History",
    "KVStore", "MajorityQuorums", "MenciusDeployment", "Network", "Node",
    "Operation", "Register", "SPaxosDeployment", "STATION_ORDER", "Station",
    "SweepSpec", "TraceStep", "TransientResult", "UnreplicatedStateMachine",
    "VARIANT_MODELS", "VariantAutotuneResult", "VariantChoice",
    "ablation_steps", "autotune", "autotune_variants", "bottleneck_trace",
    "build_schedule", "calibrate_alpha", "check_linearizable",
    "check_register_reads", "check_slot_order", "compartmentalized_model",
    "compile_models", "compile_sweep", "config_variant", "craq_chain_model",
    "craq_model", "craq_station_demands", "des_throughput",
    "failover_schedule", "fluid_throughput", "fluid_throughput_batch",
    "full_compartmentalized", "make_state_machine", "mencius_model",
    "mencius_skip_storm_schedule", "mixed_workload_speedup", "model_for",
    "multipaxos_model", "mva_curve", "mva_curves_batch",
    "mva_curves_from_demands", "noop_command", "read_scalability_law",
    "scale_schedule", "schedule_from_demands", "simulate_transient",
    "spaxos_model", "spaxos_payload_ramp_schedule", "stack_demands",
    "transient_throughput", "unreplicated_model", "vanilla_mencius_model",
    "vanilla_multipaxos", "vanilla_spaxos_model", "variant_candidate_configs",
]
