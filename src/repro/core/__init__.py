"""Compartmentalized state machine replication - the paper's contribution.

Correctness plane (deterministic, message-level):
  protocols.CompartmentalizedMultiPaxos / vanilla_multipaxos /
  UnreplicatedStateMachine, mencius.MenciusDeployment,
  spaxos.SPaxosDeployment, craq.CraqDeployment
  + linearizability checkers.

Performance plane (JAX, calibrated on the paper's anchors):
  analytical.* demand tables + bottleneck law, simulator.mva_curve /
  fluid_throughput / des_throughput.
"""
from .analytical import (
    STATION_ORDER,
    DeploymentModel,
    Station,
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
    craq_model,
    craq_station_demands,
    mixed_workload_speedup,
    multipaxos_model,
    read_scalability_law,
    stack_demands,
    unreplicated_model,
)
from .autotune import AutotuneResult, TraceStep, autotune, bottleneck_trace
from .cluster import Network, Node
from .craq import CraqDeployment
from .history import History, Operation
from .linearizability import (
    check_linearizable,
    check_register_reads,
    check_slot_order,
)
from .mencius import MenciusDeployment
from .messages import Command, noop_command
from .protocols import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
    UnreplicatedStateMachine,
    full_compartmentalized,
    vanilla_multipaxos,
)
from .quorums import GridQuorums, MajorityQuorums
from .simulator import (
    des_throughput,
    fluid_throughput,
    fluid_throughput_batch,
    mva_curve,
    mva_curves_batch,
    mva_curves_from_demands,
)
from .spaxos import SPaxosDeployment
from .sweep import (
    CompiledSweep,
    SweepSpec,
    compile_models,
    compile_sweep,
)
from .transient import (
    CRASH,
    Event,
    TransientResult,
    build_schedule,
    failover_schedule,
    scale_schedule,
    schedule_from_demands,
    simulate_transient,
    transient_throughput,
)
from .statemachine import AppendLog, KVStore, Register, make_state_machine

__all__ = [
    "AppendLog", "AutotuneResult", "CRASH", "Command",
    "CompartmentalizedMultiPaxos", "CompiledSweep", "CraqDeployment",
    "DeploymentConfig", "DeploymentModel", "Event", "GridQuorums", "History",
    "KVStore", "MajorityQuorums", "MenciusDeployment", "Network", "Node",
    "Operation", "Register", "SPaxosDeployment", "STATION_ORDER", "Station",
    "SweepSpec", "TraceStep", "TransientResult", "UnreplicatedStateMachine",
    "ablation_steps", "autotune", "bottleneck_trace", "build_schedule",
    "calibrate_alpha", "check_linearizable", "check_register_reads",
    "check_slot_order", "compartmentalized_model", "compile_models",
    "compile_sweep", "craq_model", "craq_station_demands", "des_throughput",
    "failover_schedule", "fluid_throughput", "fluid_throughput_batch",
    "full_compartmentalized", "make_state_machine", "mixed_workload_speedup",
    "multipaxos_model", "mva_curve", "mva_curves_batch",
    "mva_curves_from_demands", "noop_command", "read_scalability_law",
    "scale_schedule", "schedule_from_demands", "simulate_transient",
    "stack_demands", "transient_throughput", "unreplicated_model",
    "vanilla_multipaxos",
]
