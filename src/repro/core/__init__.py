"""Compartmentalized state machine replication - the paper's contribution.

Correctness plane (deterministic, message-level):
  protocols.CompartmentalizedMultiPaxos / vanilla_multipaxos /
  UnreplicatedStateMachine, mencius.MenciusDeployment,
  spaxos.SPaxosDeployment, craq.CraqDeployment
  + linearizability checkers.

Performance plane (JAX, calibrated on the paper's anchors):
  api.* the public surface: the pluggable variant registry
  (VariantSpec / register_variant - a protocol is a declared knob space,
  not a branch in a sweep loop) and the Workload dataclass (write mix,
  skew, arrival and batch-fill hints, passed once), analytical.* demand
  tables + bottleneck law for every registered variant,
  simulator.mva_curve / fluid_throughput / des_throughput, transient.*
  scripted dynamics, sweep.* batched mixed-variant surfaces, autotune.*
  budget search (autotune_variants across protocols).

The two planes meet in the registry: a variant that also declares an
ExecutableSpec (register_executable) executes its real cluster through
execution.run_variant - Workload-shaped traffic, linearizability check,
measured per-station msgs/cmd in canonical STATION_ORDER slots - and
execution.validate_variant reports measured-vs-analytical parity;
calibrate_alpha(measured=True) anchors alpha on an executed vanilla run.
batched_execution.* lowers those execution planes into the transient
plane's jitted scan - run_variant_batched / CompiledSweep.execute run a
whole (config x seed) grid of closed-loop clients in one device call and
emit measured msgs/cmd + latency histograms (validate_batched for parity).

Geo plane: api.GeoSpec (regions + RTT matrix + placement + client
weights) threads one WAN description through all three planes - geo.*
lowers each variant's message flow to per-region critical-path wire
latency (predict_geo_latency / wan_offsets), CompiledSweep.geo_latency
composes it with the jitted MVA queueing into a (config x region)
surface, autotune.autotune_placement searches placements under a budget,
execution.run_variant(geo=...) realizes the matrix on the real cluster
(per-region measured-vs-predicted parity via validate_variant), and
execute_configs(geo=...) fans the batched plane into per-region lanes;
transient.region_partition_schedule scripts a region dropping off the
WAN.

Autoscale plane: api.AutoscalePolicy (utilization band, hysteresis
guard, cooldown, floors/ceilings, machine budget) drives
autoscale.Controller / autoscale_grid - a closed loop on the transient
engine's own measured signals that resizes stations one server at a
time, each resize paying a transient.reconfiguration_schedule demand
spike; CompiledSweep.autoscale evaluates a whole (config x policy) grid
with one batched replay, autotune.autotune_policy ranks policies
against the frozen static baseline, and execution.run_autoscaled
replays the emitted plan on a real registered-variant cluster
(registry-derived live resize via resize_config / station_knob_map,
linearizable across every epoch, warm-phase dips parity-checked
against the transient prediction);
batched_execution.measured_capacity anchors the utilization law on the
execution plane.
"""
from .api import (
    MIXED_50_50,
    READ_HEAVY,
    UNSHARDED,
    WRITE_ONLY,
    AutoscalePolicy,
    ExecutableSpec,
    GeoSpec,
    Knob,
    ShardingSpec,
    VariantSpec,
    Workload,
    as_f_write,
    executable_variants,
    knob,
    register_executable,
    register_variant,
    registered_variants,
    resolve_workload,
    temporary_variants,
    unregister_variant,
    variant_spec,
)
from .analytical import (
    STATION_ORDER,
    VARIANT_MODELS,
    DeploymentModel,
    Station,
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
    craq_chain_model,
    craq_model,
    craq_station_demands,
    effective_batch_size,
    grids_under,
    mencius_model,
    mixed_workload_speedup,
    multipaxos_model,
    read_scalability_law,
    spaxos_model,
    stack_demands,
    unreplicated_model,
    vanilla_mencius_model,
    vanilla_spaxos_model,
)
from .autoscale import (
    AutoscaleAction,
    AutoscaleTrace,
    Controller,
    autoscale_grid,
    diurnal_load,
    flash_crowd_load,
)
from .batched_execution import (
    BatchedExecutionResult,
    BatchedParityReport,
    execute_configs,
    measured_capacity,
    run_variant_batched,
    validate_batched,
)
from .autotune import (
    AutotuneResult,
    PlacementAutotuneResult,
    PlacementChoice,
    PolicyAutotuneResult,
    PolicyChoice,
    ShardChoice,
    ShardedAutotuneResult,
    TraceStep,
    VariantAutotuneResult,
    VariantChoice,
    autotune,
    autotune_placement,
    autotune_policy,
    autotune_sharded,
    autotune_variants,
    bottleneck_trace,
    variant_candidate_configs,
)
from .bpaxos import BPaxosDeployment, bpaxos_model
from .cluster import Network, Node
from .craq import CraqDeployment
from .execution import (
    AutoscaledExecutionTrace,
    ExecutionTrace,
    ParityReport,
    ShardedDeployment,
    ShardedExecutionTrace,
    ShardedParityReport,
    StationParity,
    default_config,
    resizable_stations,
    resize_config,
    run_autoscaled,
    run_sharded,
    run_variant,
    station_knob_map,
    validate_sharded,
    validate_variant,
    workload_ops,
)
from .geo import (
    GeoLatency,
    geo_station_kinds,
    geo_variants,
    placement_candidates,
    predict_geo_latency,
    register_geo_path,
    wan_offsets,
    zero_rtt,
)
from .history import History, Operation
from .iss import IssDeployment, iss_model
from .linearizability import (
    check_linearizable,
    check_register_reads,
    check_slot_order,
)
from .mencius import MenciusDeployment
from .messages import Command, noop_command
from .sharding import (
    check_linearizable_partitioned,
    flatten_shards,
    partition_history,
    partition_ops,
    shard_column,
    shard_demands,
    shard_weights,
    split_counts,
    split_weights,
)
from .protocols import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
    UnreplicatedStateMachine,
    full_compartmentalized,
    vanilla_multipaxos,
)
from .quorums import GridQuorums, MajorityQuorums
from .simulator import (
    des_throughput,
    fluid_throughput,
    fluid_throughput_batch,
    mva_curve,
    mva_curves_batch,
    mva_curves_from_demands,
)
from .spaxos import SPaxosDeployment
from .sweep import (
    CompiledSweep,
    GeoLatencySurface,
    SweepSpec,
    compile_models,
    compile_sweep,
    config_variant,
    model_for,
)
from .transient import (
    CRASH,
    Event,
    TransientResult,
    build_schedule,
    burst_events,
    failover_schedule,
    mencius_skip_storm_schedule,
    reconfiguration_schedule,
    region_partition_schedule,
    resharding_schedule,
    scale_schedule,
    schedule_from_demands,
    simulate_transient,
    spaxos_payload_ramp_schedule,
    transient_throughput,
)
from .statemachine import AppendLog, KVStore, Register, make_state_machine

__all__ = [
    "MIXED_50_50", "READ_HEAVY", "UNSHARDED", "WRITE_ONLY",
    "AppendLog", "AutoscaleAction", "AutoscalePolicy", "AutoscaleTrace",
    "AutoscaledExecutionTrace", "AutotuneResult", "BPaxosDeployment",
    "BatchedExecutionResult",
    "BatchedParityReport", "CRASH", "Command",
    "CompartmentalizedMultiPaxos", "CompiledSweep", "Controller",
    "CraqDeployment",
    "DeploymentConfig", "DeploymentModel", "Event", "ExecutableSpec",
    "ExecutionTrace", "GeoLatency", "GeoLatencySurface", "GeoSpec",
    "GridQuorums", "History", "IssDeployment",
    "KVStore", "Knob", "MajorityQuorums", "MenciusDeployment", "Network",
    "Node", "Operation", "ParityReport", "PlacementAutotuneResult",
    "PlacementChoice", "PolicyAutotuneResult", "PolicyChoice", "Register",
    "SPaxosDeployment",
    "STATION_ORDER", "ShardChoice", "ShardedAutotuneResult",
    "ShardedDeployment", "ShardedExecutionTrace", "ShardedParityReport",
    "ShardingSpec", "Station", "StationParity", "SweepSpec", "TraceStep",
    "TransientResult",
    "UnreplicatedStateMachine", "VARIANT_MODELS", "VariantAutotuneResult",
    "VariantChoice", "VariantSpec", "Workload",
    "ablation_steps", "as_f_write", "autoscale_grid", "autotune",
    "autotune_placement",
    "autotune_policy", "autotune_sharded",
    "autotune_variants",
    "bottleneck_trace", "bpaxos_model", "build_schedule", "burst_events",
    "calibrate_alpha",
    "check_linearizable", "check_linearizable_partitioned",
    "check_register_reads", "check_slot_order",
    "compartmentalized_model", "compile_models", "compile_sweep",
    "config_variant", "craq_chain_model", "craq_model",
    "craq_station_demands", "default_config", "des_throughput",
    "diurnal_load",
    "execute_configs",
    "effective_batch_size", "executable_variants",
    "failover_schedule", "flash_crowd_load", "flatten_shards",
    "fluid_throughput", "fluid_throughput_batch",
    "full_compartmentalized", "geo_station_kinds", "geo_variants",
    "grids_under", "iss_model", "knob",
    "make_state_machine", "measured_capacity",
    "mencius_model", "mencius_skip_storm_schedule", "mixed_workload_speedup",
    "model_for", "multipaxos_model", "mva_curve", "mva_curves_batch",
    "mva_curves_from_demands", "noop_command",
    "partition_history", "partition_ops", "placement_candidates",
    "predict_geo_latency", "read_scalability_law",
    "reconfiguration_schedule",
    "register_executable", "register_geo_path", "register_variant",
    "registered_variants",
    "region_partition_schedule", "resharding_schedule", "resizable_stations",
    "resize_config", "resolve_workload",
    "run_autoscaled", "run_sharded", "run_variant", "run_variant_batched",
    "scale_schedule", "schedule_from_demands",
    "shard_column", "shard_demands", "shard_weights", "simulate_transient",
    "station_knob_map",
    "spaxos_model", "spaxos_payload_ramp_schedule",
    "split_counts", "split_weights", "stack_demands",
    "temporary_variants", "transient_throughput", "unregister_variant",
    "unreplicated_model",
    "validate_batched", "validate_sharded", "validate_variant",
    "vanilla_mencius_model", "vanilla_multipaxos",
    "vanilla_spaxos_model",
    "variant_candidate_configs", "variant_spec", "wan_offsets",
    "workload_ops", "zero_rtt",
]
