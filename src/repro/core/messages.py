"""Protocol messages for compartmentalized state machine replication.

Every message is a frozen dataclass.  Messages are exchanged between *roles*
(leader, proxy leader, acceptor, replica, batcher, unbatcher, disseminator,
stabilizer, chain node, client) through the deterministic in-process network
in :mod:`repro.core.cluster`.

Naming follows the paper (Whittaker et al., "Scaling Replicated State
Machines with Compartmentalization"): Phase1a/Phase1b/Phase2a/Phase2b,
Preread/PrereadAck, Read, Chosen, etc.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Commands
# ---------------------------------------------------------------------------

NOOP = "__noop__"


@dataclass(frozen=True)
class Command:
    """A state machine command proposed by a client.

    ``op`` is interpreted by the state machine (see ``statemachine.py``).
    ``client_id``/``client_seq`` make the command globally unique and let
    replicas route replies.  ``is_read`` marks commands that do not modify
    state (used by the leaderless read path - reads never enter the log).
    """

    client_id: int
    client_seq: int
    op: Any
    is_read: bool = False

    @property
    def uid(self) -> Tuple[int, int]:
        return (self.client_id, self.client_seq)


def noop_command() -> Command:
    return Command(client_id=-1, client_seq=-1, op=(NOOP,))


def is_noop(cmd: Command) -> bool:
    return isinstance(cmd.op, tuple) and len(cmd.op) > 0 and cmd.op[0] == NOOP


@dataclass(frozen=True)
class Batch:
    """A batch of commands formed by a batcher (compartmentalization 5)."""

    batcher_id: int
    batch_seq: int
    commands: Tuple[Command, ...]

    @property
    def uid(self) -> Tuple[int, int]:
        return (self.batcher_id, self.batch_seq)


# ---------------------------------------------------------------------------
# Client <-> protocol
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClientRequest:
    command: Command


@dataclass(frozen=True)
class ClientReply:
    command_uid: Tuple[int, int]
    result: Any
    slot: Optional[int] = None  # log index the op wrote to / read from


@dataclass(frozen=True)
class ReadRequest:
    """A linearizable read issued directly to the acceptors + a replica."""

    command: Command


# ---------------------------------------------------------------------------
# Paxos phases
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Phase1a:
    ballot: int
    # First slot the (new) leader needs information about.
    from_slot: int = 0


@dataclass(frozen=True)
class PhaseVote:
    """A single (slot, ballot, value) vote held by an acceptor."""

    slot: int
    ballot: int
    value: Any  # Command | Batch


@dataclass(frozen=True)
class Phase1b:
    ballot: int
    acceptor_id: int
    votes: Tuple[PhaseVote, ...]


@dataclass(frozen=True)
class Phase2a:
    slot: int
    ballot: int
    value: Any  # Command | Batch
    # Mencius: leaders stamp their id so acceptors can track per-leader
    # progress; -1 for plain MultiPaxos.
    leader_id: int = -1


@dataclass(frozen=True)
class Phase2b:
    slot: int
    ballot: int
    acceptor_id: int


@dataclass(frozen=True)
class Phase2aRange:
    """Mencius skip: choose noops in every ``owner``-owned slot in
    [start, stop).  Stands in for Coordinated Paxos (paper section 6.1)."""

    ballot: int
    owner: int
    start: int
    stop: int
    n_leaders: int


@dataclass(frozen=True)
class Phase2bRange:
    ballot: int
    owner: int
    start: int
    stop: int
    acceptor_id: int


@dataclass(frozen=True)
class Chosen:
    slot: int
    value: Any  # Command | Batch


@dataclass(frozen=True)
class ChosenRange:
    """Noops chosen in every owner-owned slot in [start, stop)."""

    owner: int
    start: int
    stop: int
    n_leaders: int


# ---------------------------------------------------------------------------
# Leaderless reads (compartmentalization 4, PQR-style)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Preread:
    client_id: int
    read_seq: int


@dataclass(frozen=True)
class PrereadAck:
    client_id: int
    read_seq: int
    acceptor_id: int
    vote_watermark: int  # largest slot this acceptor has voted in (-1 if none)


@dataclass(frozen=True)
class ReplicaRead:
    """Execute read ``command`` after the replica has executed slot
    ``watermark`` (paper: Read<x, i>).  ``consistency`` in
    {"linearizable", "sequential", "eventual"}."""

    command: Command
    watermark: int
    consistency: str = "linearizable"


@dataclass(frozen=True)
class ReadReply:
    command_uid: Tuple[int, int]
    result: Any
    executed_slot: int  # slot the read was served at (client watermark update)


@dataclass(frozen=True)
class ReadBatch:
    """A batch of reads with a single Preread watermark (section 4.1)."""

    commands: Tuple[Command, ...]
    watermark: int


# ---------------------------------------------------------------------------
# Batching (compartmentalizations 5 + 6)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ResultBatch:
    """Batch of results sent replica -> unbatcher (compartmentalization 6)."""

    replies: Tuple[ClientReply, ...]


# ---------------------------------------------------------------------------
# Mencius coordination
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NextSlotAnnounce:
    """Leaders periodically broadcast their next unused slot so lagging
    leaders can fill their vacant slots with noops."""

    leader_id: int
    next_slot: int


# ---------------------------------------------------------------------------
# S-Paxos dissemination
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Disseminate:
    cmd_id: Tuple[int, int]  # (disseminator_id, seq)
    command: Command


@dataclass(frozen=True)
class StabilizeAck:
    cmd_id: Tuple[int, int]
    stabilizer_id: int


@dataclass(frozen=True)
class ProposeId:
    """Disseminator -> leader: order this (stable) command id."""

    cmd_id: Tuple[int, int]


@dataclass(frozen=True)
class IdChosen:
    """Leader/proxy-leader -> stabilizer: cmd_id chosen in slot."""

    slot: int
    cmd_id: Tuple[int, int]


@dataclass(frozen=True)
class FetchCommand:
    cmd_id: Tuple[int, int]
    requester: str


@dataclass(frozen=True)
class FetchReply:
    cmd_id: Tuple[int, int]
    command: Optional[Command]


# ---------------------------------------------------------------------------
# Chain replication / CRAQ
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChainWrite:
    command: Command
    version: int = -1  # assigned by the head


@dataclass(frozen=True)
class ChainAck:
    key: Any
    version: int


@dataclass(frozen=True)
class ChainRead:
    command: Command


@dataclass(frozen=True)
class VersionQuery:
    """CRAQ: a node with a dirty key forwards the read to the tail."""

    command: Command
    origin: str


# ---------------------------------------------------------------------------
# Timers / control
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Timer:
    name: str
    payload: Any = None


@dataclass(frozen=True)
class Heartbeat:
    sender: str
    seq: int


def clone(msg, **changes):
    return dataclasses.replace(msg, **changes)
