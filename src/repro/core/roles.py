"""Compartmentalized MultiPaxos roles (paper sections 2-4).

The six compartmentalizations are realised as distinct role classes wired
together by :class:`repro.core.protocols.CompartmentalizedMultiPaxos`:

  1. proxy leaders      - ``ProxyLeader``       (decouple seq. / broadcast)
  2. acceptor grids     - ``Acceptor`` + ``GridQuorums``
  3. more replicas      - ``Replica`` (round-robin reply ownership)
  4. leaderless reads   - ``Client`` Preread path + ``Replica`` watermarks
  5. batchers           - ``Batcher``
  6. unbatchers         - ``Unbatcher``

Vanilla MultiPaxos is the same code with ``self_broadcast=True`` (the leader
does its own proxy work), majority quorums, and f+1 replicas.
"""
from __future__ import annotations

import random
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .cluster import Node
from .messages import (
    Batch,
    Chosen,
    ChosenRange,
    ClientReply,
    ClientRequest,
    Command,
    Heartbeat,
    NextSlotAnnounce,
    Phase1a,
    Phase1b,
    Phase2a,
    Phase2aRange,
    Phase2b,
    Phase2bRange,
    PhaseVote,
    Preread,
    PrereadAck,
    ReadBatch,
    ReadReply,
    ReplicaRead,
    ResultBatch,
    Timer,
    is_noop,
    noop_command,
)
from .quorums import QuorumSystem, pick_read_quorum, pick_write_quorum
from .statemachine import StateMachine

MAX_LEADERS = 64  # ballot = round * MAX_LEADERS + leader_index


# ---------------------------------------------------------------------------
# Leader
# ---------------------------------------------------------------------------


class Leader(Node):
    """Sequences commands into the log (compartmentalization 1: the leader's
    *only* job in the compartmentalized protocol).

    ``self_broadcast=True`` recovers vanilla MultiPaxos: the leader plays the
    proxy-leader role itself (Phase 2 broadcast + quorum counting).
    """

    HEARTBEAT_PERIOD = 25.0
    HEARTBEAT_MISSES = 4  # promote after this many silent periods

    def __init__(
        self,
        addr: str,
        leader_index: int,
        acceptors: Sequence[str],
        quorums: QuorumSystem,
        proxies: Sequence[str],
        replicas: Sequence[str],
        self_broadcast: bool = False,
        seed: int = 0,
        peers: Sequence[str] = (),
        auto_failover: bool = False,
        heartbeat_budget: int = 10_000,
    ) -> None:
        super().__init__(addr)
        self.leader_index = leader_index
        self.acceptors = list(acceptors)
        self.quorums = quorums
        self.proxies = list(proxies)
        self.replicas = list(replicas)
        self.self_broadcast = self_broadcast
        self.rng = random.Random(seed * 7919 + leader_index)
        # automatic failover (deterministic heartbeat timers).  NOTE: an
        # auto_failover deployment never quiesces (the tick timer
        # self-reschedules); drive it with net.run(until=T) windows.  The
        # budget is a backstop so a runaway test cannot loop forever.
        self.peers = [p for p in peers if p != addr]
        self.auto_failover = auto_failover
        self.heartbeat_budget = heartbeat_budget
        self.last_heartbeat: float = 0.0
        self._hb_seq = 0

        self.active = False
        self.round = 0
        self.ballot = leader_index
        self.next_slot = 0
        # client request buffering before phase-1 completes
        self.buffer: List[Tuple[str, ClientRequest]] = []
        # dedup: command uid -> slot
        self.assigned: Dict[Tuple[int, int], int] = {}
        self.proposals: Dict[int, Any] = {}  # slot -> value (for re-send)
        # phase 1 state
        self.p1_acks: Dict[int, Phase1b] = {}
        self.p1_quorum: FrozenSet[int] = frozenset()
        self._proxy_rr = 0
        # self-broadcast (vanilla) phase-2 state: slot -> (ballot, value, acks)
        self.pending2: Dict[int, Tuple[int, Any, Set[int]]] = {}

    # -- heartbeats / failure detection ---------------------------------------
    def start_failure_detector(self) -> None:
        """Arm heartbeat emission (active leader) / monitoring (followers)."""
        if not self.auto_failover:
            return
        self.last_heartbeat = self.now
        self.set_timer("hb_tick", self.HEARTBEAT_PERIOD)

    def _on_hb_tick(self) -> None:
        if self.heartbeat_budget <= 0:
            return
        self.heartbeat_budget -= 1
        if self.active:
            self._hb_seq += 1
            for p in self.peers:
                self.send(p, Heartbeat(sender=self.addr, seq=self._hb_seq))
        else:
            silent = self.now - self.last_heartbeat
            if silent > self.HEARTBEAT_PERIOD * self.HEARTBEAT_MISSES:
                # deterministic stagger: lower index promotes first
                delay = self.leader_index * self.HEARTBEAT_PERIOD
                self.set_timer("hb_promote", delay)
        self.set_timer("hb_tick", self.HEARTBEAT_PERIOD)

    # -- leadership ----------------------------------------------------------
    def become_leader(self) -> None:
        """Run Phase 1 over a read quorum and take over the log."""
        self.round += 1
        self.ballot = self.round * MAX_LEADERS + self.leader_index
        self.active = False
        self.p1_acks = {}
        idx, members = pick_read_quorum(self.quorums, self.rng.randrange(1 << 30))
        self.p1_quorum = members
        for a in members:
            self.send(self.acceptors[a], Phase1a(ballot=self.ballot, from_slot=0))
        self.set_timer("phase1_retry", 50.0, self.ballot)

    def _finish_phase1(self) -> None:
        # Merge votes: per slot, adopt the highest-ballot vote.
        best: Dict[int, Tuple[int, Any]] = {}
        for ack in self.p1_acks.values():
            for v in ack.votes:
                cur = best.get(v.slot)
                if cur is None or v.ballot > cur[0]:
                    best[v.slot] = (v.ballot, v.value)
        max_slot = max(best.keys(), default=-1)
        # Re-propose adopted values; fill holes with noops.
        for slot in range(0, max_slot + 1):
            value = best[slot][1] if slot in best else noop_command()
            self._propose(slot, value)
        self.next_slot = max_slot + 1
        self.active = True
        buffered, self.buffer = self.buffer, []
        for src, req in buffered:
            self.on_message(src, req)

    # -- sequencing ------------------------------------------------------------
    def _propose(self, slot: int, value: Any) -> None:
        self.proposals[slot] = value
        msg = Phase2a(slot=slot, ballot=self.ballot, value=value,
                      leader_id=self.leader_index)
        if self.self_broadcast:
            self._broadcast_phase2a(msg)
        else:
            proxy = self.proxies[self._proxy_rr % len(self.proxies)]
            self._proxy_rr += 1
            self.send(proxy, msg)

    def _broadcast_phase2a(self, msg: Phase2a) -> None:
        _, members = pick_write_quorum(self.quorums, self.rng.randrange(1 << 30))
        self.pending2[msg.slot] = (msg.ballot, msg.value, set())
        for a in members:
            self.send(self.acceptors[a], msg)

    # -- message handling ---------------------------------------------------------
    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            if not self.active:
                self.buffer.append((src, msg))
                return
            uid = msg.command.uid
            if uid in self.assigned:  # client retry: re-propose same slot
                slot = self.assigned[uid]
                self._propose(slot, self.proposals[slot])
                return
            slot = self.next_slot
            self.next_slot += 1
            self.assigned[uid] = slot
            self._propose(slot, msg.command)
        elif isinstance(msg, Batch):
            if not self.active:
                self.buffer.append((src, ClientRequest(msg)))  # type: ignore
                return
            slot = self.next_slot
            self.next_slot += 1
            self._propose(slot, msg)
        elif isinstance(msg, Phase1b):
            if msg.ballot != self.ballot or self.active:
                return
            self.p1_acks[msg.acceptor_id] = msg
            if self.p1_quorum <= set(self.p1_acks.keys()):
                self._finish_phase1()
        elif isinstance(msg, Phase2b):
            # only in self_broadcast mode
            entry = self.pending2.get(msg.slot)
            if entry is None or entry[0] != msg.ballot:
                return
            ballot, value, acks = entry
            acks.add(msg.acceptor_id)
            if self.quorums.is_write_quorum(acks):
                del self.pending2[msg.slot]
                for r in self.replicas:
                    self.send(r, Chosen(slot=msg.slot, value=value))
        elif isinstance(msg, Heartbeat):
            self.last_heartbeat = self.now
        elif isinstance(msg, Timer):
            if msg.name == "phase1_retry" and msg.payload == self.ballot and not self.active:
                self.become_leader()
            elif msg.name == "hb_tick":
                self._on_hb_tick()
            elif msg.name == "hb_promote":
                # promote only if still silent (another leader may have won)
                if (not self.active and self.now - self.last_heartbeat
                        > self.HEARTBEAT_PERIOD * self.HEARTBEAT_MISSES):
                    self.become_leader()

    def on_crash(self) -> None:
        self.active = False


# ---------------------------------------------------------------------------
# Proxy leader (compartmentalization 1)
# ---------------------------------------------------------------------------


class ProxyLeader(Node):
    """Broadcasts Phase2a messages, counts Phase2b votes, notifies replicas.

    Embarrassingly parallel: any number of proxy leaders can run side by
    side; the leader load-balances across them round-robin.
    """

    RETRY = 40.0

    def __init__(
        self,
        addr: str,
        acceptors: Sequence[str],
        quorums: QuorumSystem,
        replicas: Sequence[str],
        seed: int = 0,
        notify_extra: Sequence[str] = (),
    ) -> None:
        super().__init__(addr)
        self.acceptors = list(acceptors)
        self.quorums = quorums
        self.replicas = list(replicas)
        self.rng = random.Random(seed * 104729 + hash(addr) % 65536)
        # slot -> (ballot, value, acks, done)
        self.pending: Dict[int, Tuple[int, Any, Set[int]]] = {}
        self.done: Set[int] = set()
        self.notify_extra = list(notify_extra)  # e.g. S-Paxos stabilizers
        # Mencius skip ranges: (owner, start, stop) -> (ballot, n_leaders, acks)
        self.pending_ranges: Dict[Tuple[int, int, int], Tuple[int, int, Set[int]]] = {}

    def _notify_chosen(self, msg: Chosen | ChosenRange) -> None:
        for r in self.replicas:
            self.send(r, msg)
        for extra in self.notify_extra:
            self.send(extra, msg)

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, Phase2a):
            if msg.slot in self.done:
                return
            _, members = pick_write_quorum(self.quorums, self.rng.randrange(1 << 30))
            self.pending[msg.slot] = (msg.ballot, msg.value, set())
            for a in members:
                self.send(self.acceptors[a], msg)
            self.set_timer("p2_retry", self.RETRY, msg)
        elif isinstance(msg, Phase2b):
            entry = self.pending.get(msg.slot)
            if entry is None or entry[0] != msg.ballot:
                return
            ballot, value, acks = entry
            acks.add(msg.acceptor_id)
            if self.quorums.is_write_quorum(acks):
                del self.pending[msg.slot]
                self.done.add(msg.slot)
                self._notify_chosen(Chosen(slot=msg.slot, value=value))
        elif isinstance(msg, Phase2aRange):
            key = (msg.owner, msg.start, msg.stop)
            _, members = pick_write_quorum(self.quorums, self.rng.randrange(1 << 30))
            self.pending_ranges[key] = (msg.ballot, msg.n_leaders, set())
            for a in members:
                self.send(self.acceptors[a], msg)
        elif isinstance(msg, Phase2bRange):
            key = (msg.owner, msg.start, msg.stop)
            entry = self.pending_ranges.get(key)
            if entry is None or entry[0] != msg.ballot:
                return
            ballot, n_leaders, acks = entry
            acks.add(msg.acceptor_id)
            if self.quorums.is_write_quorum(acks):
                del self.pending_ranges[key]
                self._notify_chosen(ChosenRange(owner=msg.owner, start=msg.start,
                                                stop=msg.stop, n_leaders=n_leaders))
        elif isinstance(msg, Timer) and msg.name == "p2_retry":
            p2a = msg.payload
            entry = self.pending.get(p2a.slot)
            if entry is None or entry[0] != p2a.ballot:
                return
            # Retry non-thriftily: broadcast to *all* acceptors so any live
            # write quorum can form (tolerates acceptor failures).
            for a_addr in self.acceptors:
                self.send(a_addr, p2a)
            self.set_timer("p2_retry", self.RETRY, p2a)


# ---------------------------------------------------------------------------
# Acceptor (compartmentalization 2: arranged in grids)
# ---------------------------------------------------------------------------


class Acceptor(Node):
    """Paxos acceptor.

    Promises are tracked per *lane* (Mencius: each leader owns an independent
    ballot space for its slots) plus one global promise raised by Phase1a
    (MultiPaxos leader failover).  A Phase2a in lane ``l`` succeeds iff its
    ballot >= max(global promise, lane-l promise); plain MultiPaxos uses a
    single lane so this degenerates to the textbook acceptor.
    """

    def __init__(self, addr: str, acceptor_id: int) -> None:
        super().__init__(addr)
        self.acceptor_id = acceptor_id
        self.promised = -1  # global promise (Phase 1)
        self.lane_promised: Dict[int, int] = {}  # leader lane -> promise
        self.votes: Dict[int, Tuple[int, Any]] = {}  # slot -> (ballot, value)
        self.vote_watermark = -1  # largest slot voted in (paper: w_i)

    def _lane_floor(self, lane: int) -> int:
        return max(self.promised, self.lane_promised.get(lane, -1))

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, Phase1a):
            if msg.ballot > self.promised:
                self.promised = msg.ballot
            votes = tuple(
                PhaseVote(slot=s, ballot=b, value=v)
                for s, (b, v) in sorted(self.votes.items())
                if s >= msg.from_slot
            )
            self.send(src, Phase1b(ballot=self.promised, acceptor_id=self.acceptor_id,
                                   votes=votes))
        elif isinstance(msg, Phase2a):
            if msg.ballot >= self._lane_floor(msg.leader_id):
                self.lane_promised[msg.leader_id] = msg.ballot
                self.votes[msg.slot] = (msg.ballot, msg.value)
                if msg.slot > self.vote_watermark:
                    self.vote_watermark = msg.slot
                self.send(src, Phase2b(slot=msg.slot, ballot=msg.ballot,
                                       acceptor_id=self.acceptor_id))
        elif isinstance(msg, Phase2aRange):
            if msg.ballot >= self._lane_floor(msg.owner):
                self.lane_promised[msg.owner] = msg.ballot
                noop = noop_command()
                for slot in range(msg.start, msg.stop):
                    if slot % msg.n_leaders == msg.owner and slot not in self.votes:
                        self.votes[slot] = (msg.ballot, noop)
                        if slot > self.vote_watermark:
                            self.vote_watermark = slot
                self.send(src, Phase2bRange(ballot=msg.ballot, owner=msg.owner,
                                            start=msg.start, stop=msg.stop,
                                            acceptor_id=self.acceptor_id))
        elif isinstance(msg, Preread):
            self.send(src, PrereadAck(client_id=msg.client_id, read_seq=msg.read_seq,
                                      acceptor_id=self.acceptor_id,
                                      vote_watermark=self.vote_watermark))


# ---------------------------------------------------------------------------
# Replica (compartmentalizations 3, 4, 6)
# ---------------------------------------------------------------------------


class Replica(Node):
    """Executes the log in prefix order.

    * Replies only for slots it owns (slot % n == index) - comp. 3.
    * Serves watermarked reads without touching the leader - comp. 4.
    * Ships result batches to unbatchers - comp. 6.
    """

    def __init__(
        self,
        addr: str,
        replica_index: int,
        n_replicas: int,
        state_machine: StateMachine,
        client_addr_fn=lambda cid: f"client/{cid}",
        unbatchers: Sequence[str] = (),
        seed: int = 0,
    ) -> None:
        super().__init__(addr)
        self.replica_index = replica_index
        self.n_replicas = n_replicas
        self.sm = state_machine
        self.client_addr_fn = client_addr_fn
        self.unbatchers = list(unbatchers)
        self.rng = random.Random(seed * 6151 + replica_index)

        self.log: Dict[int, Any] = {}
        self.executed_upto = -1  # highest contiguously executed slot
        # exactly-once execution: client_id -> (last_seq, last_result)
        self.client_table: Dict[int, Tuple[int, Any]] = {}
        # reads waiting for the log to reach their watermark
        self.pending_reads: List[Tuple[int, str, Any]] = []
        self.executed_count = 0

    # -- execution ---------------------------------------------------------
    def _apply_command(self, cmd: Command) -> Optional[ClientReply]:
        if is_noop(cmd):
            return None
        last = self.client_table.get(cmd.client_id)
        if last is not None and cmd.client_seq <= last[0]:
            result = last[1] if cmd.client_seq == last[0] else None
        else:
            result = self.sm.apply_checked(cmd.op)
            self.client_table[cmd.client_id] = (cmd.client_seq, result)
        self.executed_count += 1
        return ClientReply(command_uid=cmd.uid, result=result, slot=self.executed_upto)

    def _execute_ready(self) -> None:
        while (self.executed_upto + 1) in self.log:
            slot = self.executed_upto + 1
            value = self.log[slot]
            self.executed_upto = slot
            owner = slot % self.n_replicas == self.replica_index
            if isinstance(value, Batch):
                replies = []
                for cmd in value.commands:
                    r = self._apply_command(cmd)
                    if r is not None:
                        replies.append(r)
                if owner and replies:
                    self._send_results(tuple(replies))
            else:
                r = self._apply_command(value)
                if owner and r is not None:
                    self.send(self.client_addr_fn(value.client_id), r)
        self._serve_pending_reads()

    def _send_results(self, replies: Tuple[ClientReply, ...]) -> None:
        if self.unbatchers:
            ub = self.unbatchers[self.rng.randrange(len(self.unbatchers))]
            self.send(ub, ResultBatch(replies=replies))
        else:
            for r in replies:
                self.send(self.client_addr_fn(r.command_uid[0]), r)

    # -- reads ---------------------------------------------------------------
    def _serve_read(self, src: str, msg: Any) -> None:
        if isinstance(msg, ReadBatch):
            replies = []
            for cmd in msg.commands:
                result = self.sm.apply_checked(cmd.op)
                replies.append(ClientReply(command_uid=cmd.uid, result=result,
                                           slot=self.executed_upto))
            self._send_results(tuple(replies))
        else:
            result = self.sm.apply_checked(msg.command.op)
            self.send(src, ReadReply(command_uid=msg.command.uid, result=result,
                                     executed_slot=self.executed_upto))

    def _serve_pending_reads(self) -> None:
        still = []
        for watermark, src, msg in self.pending_reads:
            if self.executed_upto >= watermark:
                self._serve_read(src, msg)
            else:
                still.append((watermark, src, msg))
        self.pending_reads = still

    # -- messages ---------------------------------------------------------------
    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, Chosen):
            if msg.slot not in self.log:
                self.log[msg.slot] = msg.value
                self._execute_ready()
        elif isinstance(msg, ChosenRange):
            noop = noop_command()
            for slot in range(msg.start, msg.stop):
                if slot % msg.n_leaders == msg.owner and slot not in self.log:
                    self.log[slot] = noop
            self._execute_ready()
        elif isinstance(msg, (ReplicaRead, ReadBatch)):
            consistency = getattr(msg, "consistency", "linearizable")
            if consistency == "eventual" or self.executed_upto >= msg.watermark:
                self._serve_read(src, msg)
            else:
                self.pending_reads.append((msg.watermark, src, msg))


# ---------------------------------------------------------------------------
# Batcher / Unbatcher (compartmentalizations 5 + 6)
# ---------------------------------------------------------------------------


class Batcher(Node):
    """Forms command batches; forwards them to the leader.  Read batches get
    a single Preread watermark and go straight to a replica (section 4.1)."""

    FLUSH_AFTER = 5.0

    def __init__(
        self,
        addr: str,
        batcher_id: int,
        leader: str,
        batch_size: int,
        acceptors: Sequence[str] = (),
        quorums: Optional[QuorumSystem] = None,
        replicas: Sequence[str] = (),
        seed: int = 0,
    ) -> None:
        super().__init__(addr)
        self.batcher_id = batcher_id
        self.leader = leader
        self.batch_size = batch_size
        self.acceptors = list(acceptors)
        self.quorums = quorums
        self.replicas = list(replicas)
        self.rng = random.Random(seed * 31 + batcher_id)

        self.writes: List[Command] = []
        self.reads: List[Command] = []
        self.batch_seq = 0
        self._timer_set = False
        # read-batch preread state: seq -> (commands, acks {aid: wm}, quorum)
        self.preread_seq = 0
        self.prereads: Dict[int, Tuple[Tuple[Command, ...], Dict[int, int], FrozenSet[int]]] = {}

    def _flush_writes(self) -> None:
        if not self.writes:
            return
        cmds, self.writes = tuple(self.writes), []
        self.send(self.leader, Batch(batcher_id=self.batcher_id,
                                     batch_seq=self.batch_seq, commands=cmds))
        self.batch_seq += 1

    def _flush_reads(self) -> None:
        if not self.reads or self.quorums is None:
            return
        cmds, self.reads = tuple(self.reads), []
        seq = self.preread_seq
        self.preread_seq += 1
        _, members = pick_read_quorum(self.quorums, self.rng.randrange(1 << 30))
        self.prereads[seq] = (cmds, {}, members)
        for a in members:
            self.send(self.acceptors[a], Preread(client_id=-(self.batcher_id + 1),
                                                 read_seq=seq))

    def _maybe_flush(self) -> None:
        if len(self.writes) >= self.batch_size:
            self._flush_writes()
        if len(self.reads) >= self.batch_size:
            self._flush_reads()
        if (self.writes or self.reads) and not self._timer_set:
            self._timer_set = True
            self.set_timer("flush", self.FLUSH_AFTER)

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            cmd = msg.command
            (self.reads if cmd.is_read else self.writes).append(cmd)
            self._maybe_flush()
        elif isinstance(msg, PrereadAck):
            entry = self.prereads.get(msg.read_seq)
            if entry is None:
                return
            cmds, acks, members = entry
            acks[msg.acceptor_id] = msg.vote_watermark
            if members <= set(acks.keys()):
                del self.prereads[msg.read_seq]
                watermark = max(acks.values(), default=-1)
                replica = self.replicas[self.rng.randrange(len(self.replicas))]
                self.send(replica, ReadBatch(commands=cmds, watermark=watermark))
        elif isinstance(msg, Timer) and msg.name == "flush":
            self._timer_set = False
            self._flush_writes()
            self._flush_reads()


class Unbatcher(Node):
    """Fans a replica's result batch back out to the clients."""

    def __init__(self, addr: str, client_addr_fn=lambda cid: f"client/{cid}") -> None:
        super().__init__(addr)
        self.client_addr_fn = client_addr_fn

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ResultBatch):
            for reply in msg.replies:
                self.send(self.client_addr_fn(reply.command_uid[0]), reply)


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------


class Client(Node):
    """Closed-loop client driving a scripted workload and recording a history
    for the linearizability checker.

    Writes go to the leader (or a random batcher).  Reads follow the paper's
    three consistency modes:

      linearizable : Preread to a read quorum -> max vote watermark ->
                     Read<x, i> at one replica  (section 3.4)
      sequential   : Read<x, w_client> at one replica (section 3.6)
      eventual     : Read<x> at one replica, executed immediately
    """

    RETRY = 400.0

    def __init__(
        self,
        addr: str,
        client_id: int,
        leader: str,
        acceptors: Sequence[str],
        quorums: QuorumSystem,
        replicas: Sequence[str],
        batchers: Sequence[str] = (),
        consistency: str = "linearizable",
        history=None,
        seed: int = 0,
        retries: bool = False,
    ) -> None:
        super().__init__(addr)
        self.client_id = client_id
        self.leader = leader
        self.acceptors = list(acceptors)
        self.quorums = quorums
        self.replicas = list(replicas)
        self.batchers = list(batchers)
        self.consistency = consistency
        self.history = history
        self.rng = random.Random(seed * 2654435761 + client_id)
        self.retries = retries

        self.seq = 0
        self.read_seq = 0
        self.watermark = -1  # sequential-consistency client watermark (w_i)
        self.ops: List[Tuple] = []
        self.op_index = 0
        self.outstanding: Optional[Tuple] = None  # (kind, op, hist_id)
        self.results: List[Any] = []
        # preread state
        self._preread_acks: Dict[int, int] = {}
        self._preread_quorum: FrozenSet[int] = frozenset()
        self._pending_read: Optional[Command] = None

    # -- workload -----------------------------------------------------------
    def run_ops(self, ops: Sequence[Tuple]) -> None:
        """Queue ops; issuing starts on the next network step."""
        self.ops.extend(ops)
        if self.outstanding is None:
            self.set_timer("kick", 0.0)

    def _issue_next(self) -> None:
        if self.op_index >= len(self.ops):
            self.outstanding = None
            return
        op = self.ops[self.op_index]
        self.op_index += 1
        is_read = self._op_is_read(op)
        hist_id = None
        if self.history is not None:
            hist_id = self.history.invoke(self.client_id, op, self.now)
        if is_read and self.consistency in ("sequential", "eventual") and self.replicas:
            cmd = Command(self.client_id, self.seq, op, is_read=True)
            self.seq += 1
            self.outstanding = ("read", cmd, hist_id)
            wm = self.watermark if self.consistency == "sequential" else -1
            replica = self.replicas[self.rng.randrange(len(self.replicas))]
            self.send(replica, ReplicaRead(command=cmd, watermark=wm,
                                           consistency=self.consistency))
        elif is_read and not self.batchers and self.acceptors:
            cmd = Command(self.client_id, self.seq, op, is_read=True)
            self.seq += 1
            self.outstanding = ("preread", cmd, hist_id)
            self._start_preread(cmd)
        else:
            cmd = Command(self.client_id, self.seq, op, is_read=is_read)
            self.seq += 1
            self.outstanding = ("write", cmd, hist_id)
            dst = (self.batchers[self.rng.randrange(len(self.batchers))]
                   if self.batchers else self.leader)
            self.send(dst, ClientRequest(command=cmd))
        if self.retries:
            self.set_timer("retry", self.RETRY, self.seq - 1)

    @staticmethod
    def _op_is_read(op: Tuple) -> bool:
        # "infer" is the serving plane's read op (model inference does not
        # modify replica state - paper section 3.4 applies verbatim)
        return op[0] in ("get", "r", "read", "infer", "read_view")

    # -- linearizable read path ------------------------------------------------
    def _start_preread(self, cmd: Command) -> None:
        self.read_seq += 1
        self._preread_acks = {}
        self._pending_read = cmd
        _, members = pick_read_quorum(self.quorums, self.rng.randrange(1 << 30))
        self._preread_quorum = members
        for a in members:
            self.send(self.acceptors[a], Preread(client_id=self.client_id,
                                                 read_seq=self.read_seq))

    # -- messages ---------------------------------------------------------------
    def _complete(self, result: Any, slot: Optional[int]) -> None:
        if self.outstanding is None:
            return
        _, _, hist_id = self.outstanding
        if self.history is not None and hist_id is not None:
            self.history.respond(hist_id, result, self.now, slot=slot)
        if slot is not None and slot > self.watermark:
            self.watermark = slot
        self.results.append(result)
        self.outstanding = None
        self._issue_next()

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientReply):
            if (self.outstanding and self.outstanding[0] in ("write", "read")
                    and msg.command_uid == self.outstanding[1].uid):
                self._complete(msg.result, msg.slot)
        elif isinstance(msg, ReadReply):
            if (self.outstanding and self.outstanding[1].uid == msg.command_uid):
                self._complete(msg.result, msg.executed_slot)
        elif isinstance(msg, PrereadAck):
            if (self.outstanding is None or self.outstanding[0] != "preread"
                    or msg.read_seq != self.read_seq):
                return
            self._preread_acks[msg.acceptor_id] = msg.vote_watermark
            if self._preread_quorum <= set(self._preread_acks.keys()):
                watermark = max(self._preread_acks.values(), default=-1)
                cmd = self._pending_read
                assert cmd is not None
                replica = self.replicas[self.rng.randrange(len(self.replicas))]
                self.send(replica, ReplicaRead(command=cmd, watermark=watermark,
                                               consistency="linearizable"))
        elif isinstance(msg, Timer):
            if msg.name == "kick" and self.outstanding is None:
                self._issue_next()
            elif (msg.name == "retry" and self.retries and self.outstanding
                  and msg.payload == self.seq - 1):
                kind, cmd, _ = self.outstanding
                if kind == "write":
                    dst = (self.batchers[self.rng.randrange(len(self.batchers))]
                           if self.batchers else self.leader)
                    self.send(dst, ClientRequest(command=cmd))
                elif kind == "preread":
                    self._start_preread(cmd)
                elif kind == "read":
                    wm = self.watermark if self.consistency == "sequential" else -1
                    replica = self.replicas[self.rng.randrange(len(self.replicas))]
                    self.send(replica, ReplicaRead(command=cmd, watermark=wm,
                                                   consistency=self.consistency))
                self.set_timer("retry", self.RETRY, msg.payload)

    @property
    def done(self) -> bool:
        return self.op_index >= len(self.ops) and self.outstanding is None
