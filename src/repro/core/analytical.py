"""Analytical performance models for the paper's evaluation (section 8).

The unit of cost is one *message* handled (sent or received) by a node; a
node processes messages at rate ``alpha`` msgs/sec.  Each protocol deployment
is reduced to a table of **per-server service demands** (expected messages a
single server of each component class handles per command).  Peak throughput
is the bottleneck law

    T_peak = alpha / max_k d_k                     (commands / sec)

and the identity of ``argmax_k d_k`` is the *bottleneck component* - the
quantity the ablation study (paper Fig. 29) tracks as compartmentalizations
are applied one by one.

The model is deliberately parameter-light: ``alpha`` is calibrated on a
single anchor (vanilla MultiPaxos = 25k cmd/s, paper Fig. 28) and everything
else is *predicted*.  ``benchmarks/protocol_messages.py`` measures the
per-role message counts on the real protocol clusters and
``docs/PERFORMANCE_MODEL.md`` documents where the structural model
under/over-predicts (it captures message counts, not JVM/Netty
implementation effects).

Demand tables cover every protocol the paper compartmentalizes, keyed by
the ``VARIANT_MODELS`` registry the sweep axis dispatches on:

* MultiPaxos (:func:`multipaxos_model` / :func:`compartmentalized_model`),
* Mencius (:func:`vanilla_mencius_model` / :func:`mencius_model`,
  paper section 6, Figs. 24-26),
* S-Paxos (:func:`vanilla_spaxos_model` / :func:`spaxos_model`,
  paper section 7, Fig. 27),
* CRAQ (:func:`craq_chain_model` for the sweep axis, :func:`craq_model`
  for the dirty-read fixed point behind Fig. 33),
* unreplicated (:func:`unreplicated_model`).

All of them lower to the same canonical :data:`STATION_ORDER` slots, so a
mixed-variant grid batches into one dense demand tensor
(:func:`stack_demands` -> :mod:`repro.core.sweep`).

Also here: the paper's closed-form read-scalability law (section 8.3)

    T(n) = n * alpha / (n * f_w + f_r)

and the CRAQ skew model backing Fig. 33.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .api import (
    STATION_INDEX,
    STATION_ORDER,
    VARIANT_MODELS,
    Workload,
    as_f_write,
    knob,
    register_variant,
)

# Paper anchor points (commands/sec), Fig. 28.
PAPER_MULTIPAXOS_UNBATCHED = 25_000.0
PAPER_COMPARTMENTALIZED_UNBATCHED = 150_000.0
PAPER_UNREPLICATED_UNBATCHED = 250_000.0
PAPER_MULTIPAXOS_BATCHED = 200_000.0
PAPER_COMPARTMENTALIZED_BATCHED = 800_000.0
PAPER_UNREPLICATED_BATCHED = 1_000_000.0

# The canonical station vocabulary (STATION_ORDER / STATION_INDEX) is
# *derived* from the variant registry in :mod:`repro.core.api`: every
# station name a registered variant declares maps to one fixed,
# append-ordered slot, so a sweep over heterogeneous deployments lowers to
# a dense [n_configs, K] tensor whose per-row argmax is directly decodable
# back to a component name.  The built-in registrations at the bottom of
# this module allocate the historical order (batcher..tail); runtime
# variants with new station names append after them.  Existing column
# indices are load-bearing for compiled sweeps and never change.


@dataclass(frozen=True)
class Station:
    """A component class: ``servers`` identical nodes, each with per-command
    service demand ``demand_write``/``demand_read`` (message units *per
    server*, i.e. already divided by fan-out across the class)."""

    name: str
    servers: int
    demand_write: float
    demand_read: float = 0.0

    def demand(self, f_write: Union[float, Workload]) -> float:
        f_w = as_f_write(f_write)
        return f_w * self.demand_write + (1.0 - f_w) * self.demand_read


@dataclass(frozen=True)
class DeploymentModel:
    name: str
    stations: Tuple[Station, ...]

    def demands(self, f_write: Union[float, Workload] = 1.0
                ) -> Dict[str, float]:
        """Per-station effective demand at a write fraction (a scalar or a
        :class:`~repro.core.api.Workload`, whose ``f_write`` is used - the
        scalar plane blends only; workload *adaptation* happens at model
        construction via the registry's ``workload_adapter``)."""
        return {s.name: s.demand(f_write) for s in self.stations}

    def bottleneck(self, f_write: Union[float, Workload] = 1.0
                   ) -> Tuple[str, float]:
        ds = self.demands(f_write)
        name = max(ds, key=ds.get)  # type: ignore[arg-type]
        return name, ds[name]

    def peak_throughput(self, alpha: float,
                        f_write: Union[float, Workload] = 1.0) -> float:
        _, d = self.bottleneck(f_write)
        return alpha / d if d > 0 else math.inf

    def total_machines(self) -> int:
        return sum(s.servers for s in self.stations)

    def demand_slots(self) -> Tuple[List[float], List[float], List[int]]:
        """Write/read demands + server counts scattered into the canonical
        :data:`STATION_ORDER` slots (zero where the deployment has no such
        component).  This is the dense row a batched sweep stacks."""
        d_w = [0.0] * len(STATION_ORDER)
        d_r = [0.0] * len(STATION_ORDER)
        srv = [0] * len(STATION_ORDER)
        for s in self.stations:
            i = STATION_INDEX[s.name]
            d_w[i] += s.demand_write
            d_r[i] += s.demand_read
            srv[i] += s.servers
        return d_w, d_r, srv


def stack_demands(models: Sequence[DeploymentModel]
                  ) -> Tuple["np.ndarray", "np.ndarray", "np.ndarray"]:
    """Lower a list of deployments to dense demand tensors.

    Returns ``(demand_write[M, K], demand_read[M, K], machines[M])`` with
    ``K = len(STATION_ORDER)``; column ``k`` of every row is the per-server
    demand of station ``STATION_ORDER[k]`` (0 where absent).  The effective
    demand matrix at write fraction ``f_w`` is
    ``f_w * demand_write + (1 - f_w) * demand_read``, its row-max the
    bottleneck-law denominator, and its row-argmax the bottleneck station.
    """
    import numpy as np

    rows_w, rows_r, rows_m = [], [], []
    for m in models:
        d_w, d_r, srv = m.demand_slots()
        rows_w.append(d_w)
        rows_r.append(d_r)
        rows_m.append(sum(srv))
    return (np.asarray(rows_w, dtype=np.float64),
            np.asarray(rows_r, dtype=np.float64),
            np.asarray(rows_m, dtype=np.int64))


# ---------------------------------------------------------------------------
# Deployment demand tables
# ---------------------------------------------------------------------------


def multipaxos_model(f: int = 1, thrifty: bool = True) -> DeploymentModel:
    """Vanilla MultiPaxos: 2f+1 machines, each proposer+acceptor+replica.

    All messages are counted (no colocation discount), matching the paper's
    own accounting (leader sends/receives >= 3f+4 messages per command).
    """
    n = 2 * f + 1
    n_repl = n  # every machine is a replica
    quorum = f + 1
    contacted = quorum if thrifty else n
    # leader machine: client recv + p2a send + p2b recv + chosen send + its
    # replica-role share of replies
    leader = 1 + contacted + quorum + n_repl + 1.0 / n_repl
    # acceptor role on a non-leader machine: thrifty quorum includes it with
    # probability contacted/n; replica role: chosen recv + reply share
    follower = 2.0 * contacted / n + 1 + 1.0 / n_repl
    return DeploymentModel(
        name=f"multipaxos(f={f})",
        stations=(
            Station("leader", 1, leader, leader),  # MP reads go through leader
            Station("follower", n - 1, follower, follower),
        ),
    )


def compartmentalized_model(
    f: int = 1,
    n_proxy_leaders: int = 10,
    grid_rows: int = 2,
    grid_cols: int = 2,
    n_replicas: int = 4,
    batch_size: int = 1,
    n_batchers: int = 0,
    n_unbatchers: int = 0,
) -> DeploymentModel:
    """Compartmentalized MultiPaxos (paper sections 3-4).

    grid: write quorum = column (``grid_rows`` members), read quorum = row
    (``grid_cols`` members).  ``batch_size=1`` means unbatched.
    """
    r, w = grid_rows, grid_cols
    n_acc = r * w
    B = float(batch_size)
    col = r  # write-quorum size
    row = w  # read-quorum size

    stations: List[Station] = []
    if n_batchers > 0:
        # per cmd: recv 1 + send 1/B (write batch to leader); reads also get
        # prereads amortized over the batch: (2*row + 1)/B
        d_w = (1 + 1 / B) / n_batchers
        d_r = (1 + (2 * row + 1) / B) / n_batchers
        stations.append(Station("batcher", n_batchers, d_w, d_r))
        leader_w = 2.0 / B
    else:
        leader_w = 2.0
    stations.append(Station("leader", 1, leader_w, 0.0))

    # proxy leader: recv p2a + send p2a to column + recv p2b from column +
    # send chosen to replicas
    proxy_per_batch = 1 + col + col + n_replicas
    stations.append(
        Station("proxy", max(n_proxy_leaders, 1),
                proxy_per_batch / B / max(n_proxy_leaders, 1), 0.0))

    # acceptor: writes hit one column (2 msgs each member) -> 2/w per write;
    # reads hit one row (2 msgs each member) -> 2/r per read
    stations.append(Station("acceptor", n_acc, 2.0 / w / B, 2.0 / r / B))

    # replica: every replica receives+executes every write; one replica
    # executes each read; replies owned round-robin (writes) / direct (reads)
    reply_cost = (1 / B) if n_unbatchers > 0 else 1.0
    d_repl_w = 1.0 / B + reply_cost / n_replicas
    d_repl_r = (1.0 / B + reply_cost) / n_replicas
    stations.append(Station("replica", n_replicas, d_repl_w, d_repl_r))

    if n_unbatchers > 0:
        d_ub = (1 / B + 1) / n_unbatchers
        stations.append(Station("unbatcher", n_unbatchers, d_ub, d_ub))

    return DeploymentModel(
        name=(f"compartmentalized(f={f},p={n_proxy_leaders},grid={r}x{w},"
              f"n={n_replicas},B={batch_size})"),
        stations=tuple(stations),
    )


def unreplicated_model(batch_size: int = 1, n_batchers: int = 0,
                       n_unbatchers: int = 0) -> DeploymentModel:
    B = float(batch_size)
    stations = [Station("server", 1, 2.0 / B, 2.0 / B)]
    if n_batchers:
        stations.append(Station("batcher", n_batchers, (1 + 1 / B) / n_batchers,
                                (1 + 1 / B) / n_batchers))
    if n_unbatchers:
        stations.append(Station("unbatcher", n_unbatchers, (1 / B + 1) / n_unbatchers,
                                (1 / B + 1) / n_unbatchers))
    return DeploymentModel(name=f"unreplicated(B={batch_size})",
                           stations=tuple(stations))


# ---------------------------------------------------------------------------
# Protocol-variant demand tables (paper sections 6-7: "compartmentalization
# is a technique, not a protocol")
# ---------------------------------------------------------------------------


def _skip_terms(skip_fraction: float, skip_batch: float) -> float:
    """Noop slots per real command, amortized by the ``Phase2aRange``
    batching factor.  ``skip_fraction`` is the fraction of *log slots*
    filled with noops by lagging leaders; each range message covers
    ``skip_batch`` noop slots, so the chosen path pays an extra
    ``skip_fraction / (1 - skip_fraction) / skip_batch`` messages per
    real command."""
    if not 0.0 <= skip_fraction < 1.0:
        raise ValueError(f"skip_fraction must be in [0, 1): {skip_fraction}")
    if skip_fraction == 0.0:
        return 0.0
    return skip_fraction / (1.0 - skip_fraction) / skip_batch


def mencius_model(
    n_leaders: int = 3,
    f: int = 1,
    n_proxy_leaders: int = 10,
    grid_rows: int = 2,
    grid_cols: int = 2,
    n_replicas: int = 4,
    announce_interval: Optional[float] = None,
    skip_fraction: float = 0.0,
    skip_batch: float = 10.0,
) -> DeploymentModel:
    """Compartmentalized Mencius (paper section 6, Figs. 24-26).

    Round-robin log partitioning: leader ``i`` of ``n_leaders`` owns slots
    ``{k : k % m == i}``, so per-leader sequencing demand is ``2/m`` (client
    recv + proxy send for the owned 1/m of commands).  Everything past the
    leaders is the MultiPaxos compartmentalization: proxy leaders, an
    ``r x w`` acceptor grid, scaled replicas, leaderless reads.

    Two overhead knobs model Mencius' slot-coordination cost:

    * ``announce_interval`` - a leader advertises its frontier to the other
      ``m - 1`` leaders every that many owned commands (``None`` = the
      paper's protocol, where frontiers piggyback on phase-2 traffic at no
      extra message cost; the correctness plane announces every command,
      i.e. ``announce_interval=1`` - the parity benchmark uses that).
    * ``skip_fraction`` - fraction of log slots noop-filled by lagging
      leaders ("skips").  Ranges amortize ``skip_batch`` noops per message
      but still traverse proxy -> grid -> replicas, so a skip storm loads
      the whole chosen path (the transient script
      :func:`repro.core.transient.mencius_skip_storm_schedule`).
    """
    m = n_leaders
    if m < 1:
        raise ValueError(f"n_leaders must be >= 1: {m}")
    r, w = grid_rows, grid_cols
    col = r  # write-quorum size (one grid column)
    noop = _skip_terms(skip_fraction, skip_batch)
    announce = 0.0
    if announce_interval:
        # per system command: the owner sends m-1 frontier messages every
        # announce_interval owned commands and every peer receives one
        announce = 2.0 * (m - 1) / announce_interval

    leader_w = (2.0 + announce + noop) / m
    proxy_per_cmd = (1 + 2 * col + n_replicas) * (1.0 + noop)
    stations = (
        Station("leader", m, leader_w, 0.0),
        Station("proxy", max(n_proxy_leaders, 1),
                proxy_per_cmd / max(n_proxy_leaders, 1), 0.0),
        Station("acceptor", r * w, 2.0 / w * (1.0 + noop), 2.0 / r),
        Station("replica", n_replicas,
                (1.0 + noop) + 1.0 / n_replicas, 2.0 / n_replicas),
    )
    return DeploymentModel(
        name=(f"mencius(m={m},p={n_proxy_leaders},grid={r}x{w},"
              f"n={n_replicas})"),
        stations=stations,
    )


def vanilla_mencius_model(
    f: int = 1,
    announce_interval: Optional[float] = None,
    skip_fraction: float = 0.0,
    skip_batch: float = 10.0,
) -> DeploymentModel:
    """Vanilla Mencius (paper Fig. 25 baseline): ``2f + 1`` servers, each
    simultaneously one of the round-robin leaders, an acceptor and a
    replica.  Load is symmetric, so a server's demand is the balanced mix
    of the MultiPaxos leader cost (for its owned ``1/m`` of commands) and
    the follower cost (for the rest), plus the announce/skip overheads of
    :func:`mencius_model`.  No leaderless read path: reads are writes."""
    m = 2 * f + 1
    quorum = f + 1
    contacted = quorum  # thrifty
    leader_cost = 1 + contacted + quorum + m + 1.0 / m
    follower_cost = 2.0 * contacted / m + 1 + 1.0 / m
    noop = _skip_terms(skip_fraction, skip_batch)
    announce = 0.0
    if announce_interval:
        announce = 2.0 * (m - 1) / announce_interval
    per_server = ((leader_cost + (m - 1) * follower_cost) * (1.0 + noop)
                  + announce) / m
    return DeploymentModel(
        name=f"vanilla_mencius(f={f})",
        stations=(Station("server", m, per_server, per_server),),
    )


def spaxos_model(
    n_disseminators: int = 2,
    n_stabilizers: int = 3,
    f: int = 1,
    n_proxy_leaders: int = 3,
    grid_rows: int = 2,
    grid_cols: int = 2,
    n_replicas: int = 3,
    payload_factor: float = 1.0,
) -> DeploymentModel:
    """Compartmentalized S-Paxos (paper section 7, Fig. 27).

    Data/control split: disseminators persist command *payloads* on every
    stabilizer (majority ack), the MultiPaxos leader orders only small
    command *ids*, and the chosen id is resolved back to a payload by one
    stabilizer which broadcasts it to the replicas.  ``payload_factor``
    scales the cost of payload-carrying messages relative to id-sized ones
    (1.0 = payloads as cheap as ids); the leader's demand is **payload
    independent** - the paper's point - which the transient script
    :func:`repro.core.transient.spaxos_payload_ramp_schedule` turns into a
    dynamics figure.

    Write path (matches ``src/repro/core/spaxos.py`` message for message):
    client -> disseminator -> all stabilizers (ack) -> leader(id) ->
    proxy -> grid column -> Chosen(id) -> one stabilizer -> replicas.
    Reads are the standard leaderless path (grid row + one replica)."""
    P = float(payload_factor)
    r, w = grid_rows, grid_cols
    col = r
    d = max(n_disseminators, 1)
    s = max(n_stabilizers, 1)
    stations = (
        # recv payload + bcast payload to stabilizers; small: acks + ProposeId
        Station("disseminator", d, (P * (1 + s) + s + 1) / d, 0.0),
        # every stabilizer: payload recv + ack; 1/s of commands: Chosen(id)
        # recv + payload bcast to replicas
        Station("stabilizer", s, (P + 1) + (1 + P * n_replicas) / s, 0.0),
        Station("leader", 1, 2.0, 0.0),       # ProposeId recv + Phase2a(id)
        Station("proxy", max(n_proxy_leaders, 1),
                (1 + 2 * col + 1) / max(n_proxy_leaders, 1), 0.0),
        Station("acceptor", r * w, 2.0 / w, 2.0 / r),
        Station("replica", n_replicas, P + 1.0 / n_replicas,
                (1.0 + P) / n_replicas),
    )
    return DeploymentModel(
        name=(f"spaxos(d={n_disseminators},s={n_stabilizers},"
              f"p={n_proxy_leaders},grid={r}x{w},n={n_replicas},P={P:g})"),
        stations=stations,
    )


def vanilla_spaxos_model(f: int = 1,
                         payload_factor: float = 1.0) -> DeploymentModel:
    """Vanilla S-Paxos (paper Fig. 27 baseline): ``2f + 1`` servers, each
    disseminator + stabilizer + acceptor + replica, with a single Paxos
    leader (on server 0) ordering ids.  The dissemination/stabilization
    roles are balanced round-robin; the leader role is not - its id-sized
    phase-2 fan-out sits on top of the shared data-path work, which is why
    vanilla S-Paxos still bottlenecks on one machine."""
    n = 2 * f + 1
    P = float(payload_factor)
    quorum = f + 1
    contacted = quorum  # thrifty
    # balanced per-server data-path work, per system command
    dis_share = (P * (1 + n) + n + 1) / n     # 1/n of commands disseminated
    stab = P + 1.0                            # every server stores + acks
    acceptor = 2.0 * contacted / n
    chosen_recv = 1.0                         # id-sized commit broadcast
    reply_share = P / n                       # round-robin payload replies
    shared = dis_share + stab + acceptor + chosen_recv + reply_share
    leader_extra = 1 + contacted + quorum + n  # ProposeId + p2a/p2b + commit
    return DeploymentModel(
        name=f"vanilla_spaxos(f={f},P={P:g})",
        stations=(
            Station("leader", 1, shared + leader_extra, shared + leader_extra),
            Station("follower", n - 1, shared, shared),
        ),
    )


def craq_chain_model(n_nodes: int = 3, skew_p: float = 0.0,
                     dirty_fraction: float = 0.0) -> DeploymentModel:
    """CRAQ as a static chain demand table for the variant sweep axis.

    ``head``/``chain``/``tail`` stations carry the chain positions.  The
    counts are message-exact against ``repro.core.craq.CraqDeployment``
    (the ``msgcount`` parity benchmark pins them): a write costs the head
    4 messages (client request in, chain write down, ack back up, client
    reply out), every interior node 4 (write + ack, both relayed), and
    the tail 2 (write in, ack out).  A read costs its serving node 2
    (request + reply *or* request + tail forward - same count either
    way); a read that hits the hot key (probability ``skew_p``) while it
    is dirty (``dirty_fraction``) and lands on a non-tail node is
    additionally forwarded to the tail (+2 there).  This is the static
    sibling of :func:`craq_station_demands`, which keeps the paper's
    Fig. 33 parameterization and solves the dirty busy-indicator as a
    throughput fixed point (:func:`craq_model`) - use that for Fig. 33,
    this factory when you want CRAQ batched into a mixed-variant sweep."""
    k = n_nodes
    if k < 2:
        raise ValueError(f"a chain needs >= 2 nodes: {k}")
    p_fwd = skew_p * dirty_fraction
    read_local = 2.0 / k  # uniformly addressed; served or forwarded, 2 msgs
    stations = [Station("head", 1, 4.0, read_local)]
    if k > 2:
        stations.append(Station("chain", k - 2, 4.0, read_local))
    stations.append(
        Station("tail", 1, 2.0, read_local + p_fwd * 2.0 * (k - 1) / k))
    return DeploymentModel(
        name=f"craq(k={k},p={skew_p:g},dirty={dirty_fraction:g})",
        stations=tuple(stations),
    )


# (The pre-registry VARIANT_MODELS dict lived here; it is now a live view
# of the :mod:`repro.core.api` registry, populated by the built-in
# registrations at the bottom of this module.)


def craq_station_demands(n_nodes: int, skew_p: float, f_write: float,
                         alpha: float, T: float,
                         commit_latency_cmds: float = 8.0) -> List[float]:
    """Per-node CRAQ message demands at offered throughput ``T`` (the
    demand mapping behind :func:`craq_model`, exposed so time-varying skew
    schedules can feed the transient engine a chain-demand vector per
    window - paper Fig. 33 as dynamics).

    With probability ``skew_p`` an op targets hot key 0; otherwise a
    uniform cold key.  A read of a *dirty* key is forwarded to the tail;
    the hot key is dirty whenever one of its writes is in flight
    (M/G/inf busy indicator with commit time ``C``)."""
    f_write = as_f_write(f_write)
    k = n_nodes
    lam_w_hot = T * f_write * skew_p
    C = commit_latency_cmds * (2.0 * k) / alpha
    dirty = 1.0 - math.exp(-lam_w_hot * C)
    f_read = 1.0 - f_write
    # every node: writes cost 4 msgs (fwd recv/send + ack recv/send);
    # head also takes client recv + reply send
    demands = []
    for i in range(k):
        d = f_write * 4.0
        if i == 0:
            d += f_write * 2.0
        # reads: uniformly addressed; clean served locally (2 msgs)
        p_fwd = skew_p * dirty
        d += f_read * ((1.0 - p_fwd) * 2.0 / k + p_fwd * (1.0 / k))
        if i == k - 1:  # tail: all forwarded reads + its own share
            d += f_read * p_fwd * 2.0
        demands.append(d)
    return demands


def craq_model(n_nodes: int, skew_p: float, f_write: float,
               alpha: float, commit_latency_cmds: float = 8.0) -> float:
    """CRAQ peak throughput under the paper's skew workload (section 8.4).

    Solves for the fixed point T where the bottleneck node of
    :func:`craq_station_demands` saturates.
    """
    T = alpha / 4.0
    for _ in range(200):
        d = max(craq_station_demands(n_nodes, skew_p, f_write, alpha, T,
                                     commit_latency_cmds))
        T_new = alpha / d
        if abs(T_new - T) < 1e-6 * alpha:
            T = T_new
            break
        T = 0.5 * T + 0.5 * T_new
    return T


# ---------------------------------------------------------------------------
# Calibration + the paper's closed-form law
# ---------------------------------------------------------------------------


def calibrate_alpha(anchor_throughput: float = PAPER_MULTIPAXOS_UNBATCHED,
                    model: Optional[DeploymentModel] = None,
                    f_write: float = 1.0,
                    measured: bool = False,
                    n_commands: int = 40,
                    seed: int = 0,
                    geo: Optional[Any] = None) -> float:
    """alpha such that the anchor deployment peaks at ``anchor_throughput``
    (vanilla MultiPaxos = 25k cmd/s, paper Fig. 28).

    With ``measured=False`` (default) the bottleneck demand comes from the
    anchor's demand *table*.  With ``measured=True`` it is read off an
    **executed** vanilla MultiPaxos run instead of a constant: the
    ``multipaxos`` variant's registered execution plane
    (``repro.core.execution.run_variant``) drives the real cluster and the
    measured per-server messages per command of its bottleneck station
    become the calibration denominator - the 25k anchor then rests on the
    correctness plane, not on the table it is meant to validate.
    ``measured=True`` requires the default anchor (``model=None``).

    ``geo`` (a :class:`~repro.core.api.GeoSpec`, ``measured=True`` only)
    calibrates off a geo-deployed anchor while keeping alpha a *local*
    per-node rate: WAN round trips stretch the run's wall-clock but add
    no per-server work, so the measured-vs-table deviation of the
    bottleneck demand is rescaled by the fraction of the measured mean
    latency that modeled WAN wire time (:func:`repro.core.geo.
    wan_offsets`) does NOT explain - ``d_corr = d_pred + (d_meas -
    d_pred) * r_local / r_total``.  With ``geo=None`` or a uniform
    matrix the correction is exactly the identity, pinning the
    historical calibration value."""
    if measured:
        if model is not None:
            raise TypeError(
                "calibrate_alpha: measured=True executes the registered "
                "'multipaxos' anchor; pass model=None")
        # lazy import: execution imports this module (no cycle at import)
        from .execution import run_variant
        trace = run_variant("multipaxos", workload=Workload(f_write=f_write),
                            n_commands=n_commands, seed=seed, geo=geo)
        d_meas = max(trace.station_msgs.values())
        if geo is None or geo.is_uniform:
            return anchor_throughput * d_meas
        from .geo import wan_offsets
        _, d_pred = multipaxos_model().bottleneck(f_write)
        counts = {name: w + r for name, (w, r) in trace.region_ops.items()}
        total = max(sum(counts.values()), 1)
        r_total = sum(trace.region_latency[name] * n
                      for name, n in counts.items()) / total
        off = wan_offsets({"variant": "multipaxos"}, geo,
                          workload=Workload(f_write=f_write),
                          n_clients=trace.geo_n_clients)
        wan = sum(off[list(geo.regions).index(name)] * n
                  for name, n in counts.items()) / total
        r_local = max(r_total - wan, 1e-12)
        d_corr = d_pred + (d_meas - d_pred) * r_local / max(r_total, 1e-12)
        return anchor_throughput * d_corr
    if geo is not None:
        raise TypeError("calibrate_alpha: geo= requires measured=True "
                        "(the table path has no cluster to deploy on)")
    model = model or multipaxos_model()
    _, d = model.bottleneck(f_write)
    return anchor_throughput * d


def read_scalability_law(n_replicas: float, f_write: Union[float, Workload],
                         alpha_replica: float) -> float:
    """Paper section 8.3:  T = n*alpha / (n*f_w + f_r)."""
    f_write = as_f_write(f_write)
    f_read = 1.0 - f_write
    return n_replicas * alpha_replica / (n_replicas * f_write + f_read)


def ablation_steps(f: int = 1) -> List[Tuple[str, DeploymentModel]]:
    """The paper's Fig. 29a sequence: decouple, then scale each bottleneck."""
    return [
        ("multipaxos", multipaxos_model(f=f)),
        ("decoupled (2 proxies, 3 acc, 2 repl)",
         compartmentalized_model(f=f, n_proxy_leaders=2, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("3 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=3, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("5 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=5, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("7 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=7, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("3 replicas",
         compartmentalized_model(f=f, n_proxy_leaders=7, grid_rows=3, grid_cols=1,
                                 n_replicas=3)),
        ("10 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=10, grid_rows=3, grid_cols=1,
                                 n_replicas=3)),
        ("paper deployment (10 proxies, 2x2 grid, 4 replicas)",
         compartmentalized_model(f=f, n_proxy_leaders=10, grid_rows=2, grid_cols=2,
                                 n_replicas=4)),
    ]


def mixed_workload_speedup(f_write: float, alpha: float,
                           n_replicas: int = 6) -> Tuple[float, float, float]:
    """(T_multipaxos, T_compartmentalized, speedup) for a read/write mix.

    MultiPaxos treats reads as writes (no read path); compartmentalized
    MultiPaxos serves reads from single replicas (the 16x headline claim is a
    90% read workload, paper section 10)."""
    mp = multipaxos_model(f=1).peak_throughput(alpha, f_write=1.0)
    cmp_model = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=4,
                                        grid_cols=4, n_replicas=n_replicas)
    cm = cmp_model.peak_throughput(alpha, f_write=f_write)
    return mp, cm, cm / mp


# ---------------------------------------------------------------------------
# Built-in variant registrations (the registry the whole performance plane
# dispatches on - see repro.core.api; runtime variants register the same
# way with ZERO edits to this file)
# ---------------------------------------------------------------------------


def grids_under(max_cells: int, f: int) -> List[Tuple[int, int]]:
    """Acceptor grids with write quorums (columns) of >= f + 1 members and
    at most ``max_cells`` acceptors, plus the (2f+1, 1) majority column."""
    grids: List[Tuple[int, int]] = [(2 * f + 1, 1)]
    for rows in range(f + 1, max(max_cells, f + 1) + 1):
        for cols in range(1, max(max_cells // rows, 1) + 1):
            if rows * cols <= max_cells and (rows, cols) not in grids:
                grids.append((rows, cols))
    return grids


def effective_batch_size(batch_size: int, batch_fill: float) -> int:
    """Batch size actually achieved at a fill fraction: under sparse or
    bursty arrivals batches close before ``B`` commands accumulate, so the
    amortization a batcher buys shrinks to ``1 + (B - 1) * fill``."""
    return max(1, int(round(1 + (batch_size - 1) * batch_fill)))


def _batch_fill_adapter(config: Dict, workload: Workload) -> Dict:
    """Workload adapter for batched variants: scale the config's batch
    size by the workload's fill hint (no-op at full batches)."""
    B = int(config.get("batch_size", 1))
    if workload.batch_fill >= 1.0 or B <= 1:
        return config
    return {**config, "batch_size": effective_batch_size(B, workload.batch_fill)}


def _craq_workload_adapter(config: Dict, workload: Workload) -> Dict:
    """Workload adapter for CRAQ: skewed reads hit the hot key with
    probability ``skew_p`` and forward to the tail while it is dirty -
    the config inherits the workload's skew hints unless it pins its own."""
    if workload.skew_p <= 0.0 or "skew_p" in config:
        return config
    return {**config, "skew_p": workload.skew_p,
            "dirty_fraction": workload.dirty_fraction}


def _compartmentalized_candidates(budget: int, f: int) -> Dict[str, tuple]:
    """The unbatched discrete config space under a machine budget (knob
    ranges clipped so the smallest other components still fit)."""
    min_grid = f + 1                       # the (f+1, 1) column grid
    min_rest = 1 + min_grid + (f + 1)      # leader + smallest grid + replicas
    max_proxies = max(budget - min_rest, 1)
    max_replicas = max(budget - (1 + 1 + min_grid), f + 1)
    max_grid = budget - (1 + 1 + (f + 1))  # leader + 1 proxy + f+1 replicas
    return {
        "n_proxy_leaders": tuple(range(1, max_proxies + 1)),
        "grids": tuple(grids_under(max_grid, f)),
        "n_replicas": tuple(range(f + 1, max_replicas + 1)),
    }


def _mencius_candidates(budget: int, f: int) -> Dict[str, tuple]:
    """Coarsened Mencius candidate space (the extra leader axis would
    otherwise blow up the cartesian product)."""
    min_grid = f + 1
    max_proxies = max(budget - (1 + min_grid + (f + 1)), 1)
    max_replicas = max(budget - (1 + 1 + min_grid), f + 1)
    return {
        "n_leaders": tuple(range(1, min(budget, 5) + 1)),
        "n_proxy_leaders": tuple(range(1, min(max_proxies, 8) + 1)),
        "grids": ((2 * f + 1, 1), (f + 1, f + 1)),
        "n_replicas": tuple(range(f + 1, min(max_replicas, f + 7) + 1)),
    }


def _spaxos_candidates(budget: int, f: int) -> Dict[str, tuple]:
    """Coarsened S-Paxos candidate space (disseminator/stabilizer axes)."""
    min_grid = f + 1
    max_proxies = max(budget - (1 + min_grid + (f + 1)), 1)
    max_replicas = max(budget - (1 + 1 + min_grid), f + 1)
    return {
        "n_disseminators": tuple(range(1, min(budget, 6) + 1)),
        "n_stabilizers": (2 * f + 1, 2 * f + 3),
        "n_proxy_leaders": tuple(range(1, min(max_proxies, 6) + 1)),
        "grids": ((2 * f + 1, 1), (f + 1, f + 1)),
        "n_replicas": tuple(range(f + 1, min(max_replicas, f + 5) + 1)),
    }


def _craq_candidates(budget: int, f: int) -> Dict[str, tuple]:
    return {"chain_nodes": tuple(range(2, min(budget, 7) + 1))}


# Registration order is load-bearing for *new* station names only: this
# sequence reproduces the historical STATION_ORDER slot layout exactly
# (batcher, leader, proxy, acceptor, replica, unbatcher, server, follower,
# disseminator, stabilizer, head, chain, tail).
register_variant(
    name="compartmentalized",
    factory=compartmentalized_model,
    stations=("batcher", "leader", "proxy", "acceptor", "replica",
              "unbatcher"),
    knobs=(
        knob("n_proxy_leaders", (10,)),
        knob("grids", ((2, 2),), keys=("grid_rows", "grid_cols")),
        knob("n_replicas", (4,)),
        knob("batch_sizes", (1,), keys=("batch_size",)),
        knob("n_batchers", (0,)),
        knob("n_unbatchers", (0,)),
    ),
    takes_f=True,
    implicit_variant_key=True,  # pre-registry config dicts omit "variant"
    workload_adapter=_batch_fill_adapter,
    candidate_knobs=_compartmentalized_candidates,
    description="Compartmentalized MultiPaxos (paper sections 3-4)",
)

register_variant(
    name="unreplicated",
    factory=unreplicated_model,
    stations=("server", "batcher", "unbatcher"),
    takes_f=False,
    workload_adapter=_batch_fill_adapter,
    description="Unreplicated state machine baseline (paper Fig. 28)",
)

register_variant(
    name="multipaxos",
    factory=multipaxos_model,
    stations=("leader", "follower"),
    description="Vanilla MultiPaxos baseline (2f+1 fused servers)",
)

register_variant(
    name="mencius",
    factory=mencius_model,
    stations=("leader", "proxy", "acceptor", "replica"),
    knobs=(
        knob("n_leaders", (3,)),
        knob("n_proxy_leaders", (10,)),
        knob("grids", ((2, 2),), keys=("grid_rows", "grid_cols")),
        knob("n_replicas", (4,)),
    ),
    candidate_knobs=_mencius_candidates,
    description="Compartmentalized Mencius (paper section 6, Figs. 24-26)",
)

register_variant(
    name="vanilla_mencius",
    factory=vanilla_mencius_model,
    stations=("server",),
    description="Vanilla Mencius baseline (paper Fig. 25)",
)

register_variant(
    name="spaxos",
    factory=spaxos_model,
    stations=("disseminator", "stabilizer", "leader", "proxy", "acceptor",
              "replica"),
    knobs=(
        knob("n_disseminators", (2,)),
        knob("n_stabilizers", (3,)),
        knob("n_proxy_leaders", (10,)),
        knob("grids", ((2, 2),), keys=("grid_rows", "grid_cols")),
        knob("n_replicas", (4,)),
    ),
    candidate_knobs=_spaxos_candidates,
    description="Compartmentalized S-Paxos (paper section 7, Fig. 27)",
)

register_variant(
    name="vanilla_spaxos",
    factory=vanilla_spaxos_model,
    stations=("leader", "follower"),
    description="Vanilla S-Paxos baseline (paper Fig. 27)",
)

register_variant(
    name="craq",
    factory=craq_chain_model,
    stations=("head", "chain", "tail"),
    knobs=(knob("chain_nodes", (3,), keys=("n_nodes",)),),
    takes_f=False,
    workload_adapter=_craq_workload_adapter,
    candidate_knobs=_craq_candidates,
    description="CRAQ chain comparison (paper section 8.4, Fig. 33)",
)
