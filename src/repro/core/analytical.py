"""Analytical performance models for the paper's evaluation (section 8).

The unit of cost is one *message* handled (sent or received) by a node; a
node processes messages at rate ``alpha`` msgs/sec.  Each protocol deployment
is reduced to a table of **per-server service demands** (expected messages a
single server of each component class handles per command).  Peak throughput
is the bottleneck law

    T_peak = alpha / max_k d_k                     (commands / sec)

and the identity of ``argmax_k d_k`` is the *bottleneck component* - the
quantity the ablation study (paper Fig. 29) tracks as compartmentalizations
are applied one by one.

The model is deliberately parameter-light: ``alpha`` is calibrated on a
single anchor (vanilla MultiPaxos = 25k cmd/s, paper Fig. 28) and everything
else is *predicted*.  ``EXPERIMENTS.md`` reports predictions vs the paper's
measurements, including where the structural model underpredicts (it captures
message counts, not JVM/Netty implementation effects).

Also here: the paper's closed-form read-scalability law (section 8.3)

    T(n) = n * alpha / (n * f_w + f_r)

and the CRAQ skew model backing Fig. 33.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

# Paper anchor points (commands/sec), Fig. 28.
PAPER_MULTIPAXOS_UNBATCHED = 25_000.0
PAPER_COMPARTMENTALIZED_UNBATCHED = 150_000.0
PAPER_UNREPLICATED_UNBATCHED = 250_000.0
PAPER_MULTIPAXOS_BATCHED = 200_000.0
PAPER_COMPARTMENTALIZED_BATCHED = 800_000.0
PAPER_UNREPLICATED_BATCHED = 1_000_000.0

# Canonical station vocabulary for batched/stacked demand export.  Every
# station name any deployment factory emits maps to one fixed slot, so a
# sweep over heterogeneous deployments lowers to a dense [n_configs, K]
# tensor whose per-row argmax is directly decodable back to a component name.
STATION_ORDER: Tuple[str, ...] = (
    "batcher", "leader", "proxy", "acceptor", "replica", "unbatcher",
    "server", "follower",
)
STATION_INDEX: Dict[str, int] = {name: i for i, name in enumerate(STATION_ORDER)}


@dataclass(frozen=True)
class Station:
    """A component class: ``servers`` identical nodes, each with per-command
    service demand ``demand_write``/``demand_read`` (message units *per
    server*, i.e. already divided by fan-out across the class)."""

    name: str
    servers: int
    demand_write: float
    demand_read: float = 0.0

    def demand(self, f_write: float) -> float:
        return f_write * self.demand_write + (1.0 - f_write) * self.demand_read


@dataclass(frozen=True)
class DeploymentModel:
    name: str
    stations: Tuple[Station, ...]

    def demands(self, f_write: float = 1.0) -> Dict[str, float]:
        return {s.name: s.demand(f_write) for s in self.stations}

    def bottleneck(self, f_write: float = 1.0) -> Tuple[str, float]:
        ds = self.demands(f_write)
        name = max(ds, key=ds.get)  # type: ignore[arg-type]
        return name, ds[name]

    def peak_throughput(self, alpha: float, f_write: float = 1.0) -> float:
        _, d = self.bottleneck(f_write)
        return alpha / d if d > 0 else math.inf

    def total_machines(self) -> int:
        return sum(s.servers for s in self.stations)

    def demand_slots(self) -> Tuple[List[float], List[float], List[int]]:
        """Write/read demands + server counts scattered into the canonical
        :data:`STATION_ORDER` slots (zero where the deployment has no such
        component).  This is the dense row a batched sweep stacks."""
        d_w = [0.0] * len(STATION_ORDER)
        d_r = [0.0] * len(STATION_ORDER)
        srv = [0] * len(STATION_ORDER)
        for s in self.stations:
            i = STATION_INDEX[s.name]
            d_w[i] += s.demand_write
            d_r[i] += s.demand_read
            srv[i] += s.servers
        return d_w, d_r, srv


def stack_demands(models: Sequence[DeploymentModel]
                  ) -> Tuple["np.ndarray", "np.ndarray", "np.ndarray"]:
    """Lower a list of deployments to dense demand tensors.

    Returns ``(demand_write[M, K], demand_read[M, K], machines[M])`` with
    ``K = len(STATION_ORDER)``; column ``k`` of every row is the per-server
    demand of station ``STATION_ORDER[k]`` (0 where absent).  The effective
    demand matrix at write fraction ``f_w`` is
    ``f_w * demand_write + (1 - f_w) * demand_read``, its row-max the
    bottleneck-law denominator, and its row-argmax the bottleneck station.
    """
    import numpy as np

    rows_w, rows_r, rows_m = [], [], []
    for m in models:
        d_w, d_r, srv = m.demand_slots()
        rows_w.append(d_w)
        rows_r.append(d_r)
        rows_m.append(sum(srv))
    return (np.asarray(rows_w, dtype=np.float64),
            np.asarray(rows_r, dtype=np.float64),
            np.asarray(rows_m, dtype=np.int64))


# ---------------------------------------------------------------------------
# Deployment demand tables
# ---------------------------------------------------------------------------


def multipaxos_model(f: int = 1, thrifty: bool = True) -> DeploymentModel:
    """Vanilla MultiPaxos: 2f+1 machines, each proposer+acceptor+replica.

    All messages are counted (no colocation discount), matching the paper's
    own accounting (leader sends/receives >= 3f+4 messages per command).
    """
    n = 2 * f + 1
    n_repl = n  # every machine is a replica
    quorum = f + 1
    contacted = quorum if thrifty else n
    # leader machine: client recv + p2a send + p2b recv + chosen send + its
    # replica-role share of replies
    leader = 1 + contacted + quorum + n_repl + 1.0 / n_repl
    # acceptor role on a non-leader machine: thrifty quorum includes it with
    # probability contacted/n; replica role: chosen recv + reply share
    follower = 2.0 * contacted / n + 1 + 1.0 / n_repl
    return DeploymentModel(
        name=f"multipaxos(f={f})",
        stations=(
            Station("leader", 1, leader, leader),  # MP reads go through leader
            Station("follower", n - 1, follower, follower),
        ),
    )


def compartmentalized_model(
    f: int = 1,
    n_proxy_leaders: int = 10,
    grid_rows: int = 2,
    grid_cols: int = 2,
    n_replicas: int = 4,
    batch_size: int = 1,
    n_batchers: int = 0,
    n_unbatchers: int = 0,
) -> DeploymentModel:
    """Compartmentalized MultiPaxos (paper sections 3-4).

    grid: write quorum = column (``grid_rows`` members), read quorum = row
    (``grid_cols`` members).  ``batch_size=1`` means unbatched.
    """
    r, w = grid_rows, grid_cols
    n_acc = r * w
    B = float(batch_size)
    col = r  # write-quorum size
    row = w  # read-quorum size

    stations: List[Station] = []
    if n_batchers > 0:
        # per cmd: recv 1 + send 1/B (write batch to leader); reads also get
        # prereads amortized over the batch: (2*row + 1)/B
        d_w = (1 + 1 / B) / n_batchers
        d_r = (1 + (2 * row + 1) / B) / n_batchers
        stations.append(Station("batcher", n_batchers, d_w, d_r))
        leader_w = 2.0 / B
    else:
        leader_w = 2.0
    stations.append(Station("leader", 1, leader_w, 0.0))

    # proxy leader: recv p2a + send p2a to column + recv p2b from column +
    # send chosen to replicas
    proxy_per_batch = 1 + col + col + n_replicas
    stations.append(
        Station("proxy", max(n_proxy_leaders, 1),
                proxy_per_batch / B / max(n_proxy_leaders, 1), 0.0))

    # acceptor: writes hit one column (2 msgs each member) -> 2/w per write;
    # reads hit one row (2 msgs each member) -> 2/r per read
    stations.append(Station("acceptor", n_acc, 2.0 / w / B, 2.0 / r / B))

    # replica: every replica receives+executes every write; one replica
    # executes each read; replies owned round-robin (writes) / direct (reads)
    reply_cost = (1 / B) if n_unbatchers > 0 else 1.0
    d_repl_w = 1.0 / B + reply_cost / n_replicas
    d_repl_r = (1.0 / B + reply_cost) / n_replicas
    stations.append(Station("replica", n_replicas, d_repl_w, d_repl_r))

    if n_unbatchers > 0:
        d_ub = (1 / B + 1) / n_unbatchers
        stations.append(Station("unbatcher", n_unbatchers, d_ub, d_ub))

    return DeploymentModel(
        name=(f"compartmentalized(f={f},p={n_proxy_leaders},grid={r}x{w},"
              f"n={n_replicas},B={batch_size})"),
        stations=tuple(stations),
    )


def unreplicated_model(batch_size: int = 1, n_batchers: int = 0,
                       n_unbatchers: int = 0) -> DeploymentModel:
    B = float(batch_size)
    stations = [Station("server", 1, 2.0 / B, 2.0 / B)]
    if n_batchers:
        stations.append(Station("batcher", n_batchers, (1 + 1 / B) / n_batchers,
                                (1 + 1 / B) / n_batchers))
    if n_unbatchers:
        stations.append(Station("unbatcher", n_unbatchers, (1 / B + 1) / n_unbatchers,
                                (1 / B + 1) / n_unbatchers))
    return DeploymentModel(name=f"unreplicated(B={batch_size})",
                           stations=tuple(stations))


def craq_station_demands(n_nodes: int, skew_p: float, f_write: float,
                         alpha: float, T: float,
                         commit_latency_cmds: float = 8.0) -> List[float]:
    """Per-node CRAQ message demands at offered throughput ``T`` (the
    demand mapping behind :func:`craq_model`, exposed so time-varying skew
    schedules can feed the transient engine a chain-demand vector per
    window - paper Fig. 33 as dynamics).

    With probability ``skew_p`` an op targets hot key 0; otherwise a
    uniform cold key.  A read of a *dirty* key is forwarded to the tail;
    the hot key is dirty whenever one of its writes is in flight
    (M/G/inf busy indicator with commit time ``C``)."""
    k = n_nodes
    lam_w_hot = T * f_write * skew_p
    C = commit_latency_cmds * (2.0 * k) / alpha
    dirty = 1.0 - math.exp(-lam_w_hot * C)
    f_read = 1.0 - f_write
    # every node: writes cost 4 msgs (fwd recv/send + ack recv/send);
    # head also takes client recv + reply send
    demands = []
    for i in range(k):
        d = f_write * 4.0
        if i == 0:
            d += f_write * 2.0
        # reads: uniformly addressed; clean served locally (2 msgs)
        p_fwd = skew_p * dirty
        d += f_read * ((1.0 - p_fwd) * 2.0 / k + p_fwd * (1.0 / k))
        if i == k - 1:  # tail: all forwarded reads + its own share
            d += f_read * p_fwd * 2.0
        demands.append(d)
    return demands


def craq_model(n_nodes: int, skew_p: float, f_write: float,
               alpha: float, commit_latency_cmds: float = 8.0) -> float:
    """CRAQ peak throughput under the paper's skew workload (section 8.4).

    Solves for the fixed point T where the bottleneck node of
    :func:`craq_station_demands` saturates.
    """
    T = alpha / 4.0
    for _ in range(200):
        d = max(craq_station_demands(n_nodes, skew_p, f_write, alpha, T,
                                     commit_latency_cmds))
        T_new = alpha / d
        if abs(T_new - T) < 1e-6 * alpha:
            T = T_new
            break
        T = 0.5 * T + 0.5 * T_new
    return T


# ---------------------------------------------------------------------------
# Calibration + the paper's closed-form law
# ---------------------------------------------------------------------------


def calibrate_alpha(anchor_throughput: float = PAPER_MULTIPAXOS_UNBATCHED,
                    model: Optional[DeploymentModel] = None,
                    f_write: float = 1.0) -> float:
    """alpha such that ``model`` peaks at ``anchor_throughput``."""
    model = model or multipaxos_model()
    _, d = model.bottleneck(f_write)
    return anchor_throughput * d


def read_scalability_law(n_replicas: float, f_write: float,
                         alpha_replica: float) -> float:
    """Paper section 8.3:  T = n*alpha / (n*f_w + f_r)."""
    f_read = 1.0 - f_write
    return n_replicas * alpha_replica / (n_replicas * f_write + f_read)


def ablation_steps(f: int = 1) -> List[Tuple[str, DeploymentModel]]:
    """The paper's Fig. 29a sequence: decouple, then scale each bottleneck."""
    return [
        ("multipaxos", multipaxos_model(f=f)),
        ("decoupled (2 proxies, 3 acc, 2 repl)",
         compartmentalized_model(f=f, n_proxy_leaders=2, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("3 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=3, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("5 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=5, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("7 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=7, grid_rows=3, grid_cols=1,
                                 n_replicas=2)),
        ("3 replicas",
         compartmentalized_model(f=f, n_proxy_leaders=7, grid_rows=3, grid_cols=1,
                                 n_replicas=3)),
        ("10 proxy leaders",
         compartmentalized_model(f=f, n_proxy_leaders=10, grid_rows=3, grid_cols=1,
                                 n_replicas=3)),
        ("paper deployment (10 proxies, 2x2 grid, 4 replicas)",
         compartmentalized_model(f=f, n_proxy_leaders=10, grid_rows=2, grid_cols=2,
                                 n_replicas=4)),
    ]


def mixed_workload_speedup(f_write: float, alpha: float,
                           n_replicas: int = 6) -> Tuple[float, float, float]:
    """(T_multipaxos, T_compartmentalized, speedup) for a read/write mix.

    MultiPaxos treats reads as writes (no read path); compartmentalized
    MultiPaxos serves reads from single replicas (the 16x headline claim is a
    90% read workload, paper section 10)."""
    mp = multipaxos_model(f=1).peak_throughput(alpha, f_write=1.0)
    cmp_model = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=4,
                                        grid_cols=4, n_replicas=n_replicas)
    cm = cmp_model.peak_throughput(alpha, f_write=f_write)
    return mp, cm, cm / mp
