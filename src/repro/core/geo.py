"""Critical-path WAN latency models: the geo plane's analytical side.

Every analytical model in :mod:`repro.core.analytical` counts *messages
per command* - a throughput currency.  This module lowers the SAME
registered message flows into *critical-path WAN round trips*: given a
:class:`~repro.core.api.GeoSpec` (regions, RTT matrix, placement, client
weights), each variant's per-op-class latency is the sum of hop delays
along the chain the real cluster walks, with quorum legs taken in
expectation over the deployment's uniform-random quorum picks:

* a one-way hop between regions ``i`` and ``j`` costs
  ``local_delay + one_way(i, j)`` (``one_way = rtt/2``, 0 intra-region),
  exactly :meth:`GeoSpec.hop_delay` - the function the execution plane's
  ``Network.latency_fn`` realizes, so measured and predicted latency are
  two views of one number;
* a *broadcast-wait-quorum* leg (Phase 2a/2b, S-Paxos stabilization,
  BPaxos dependency service) is the k-th smallest round trip when the
  sender broadcasts to everyone, or ``E[max over quorum members]`` when
  the sender picks one quorum uniformly at random (the deployments'
  ``pick_write_quorum`` / ``pick_read_quorum``);
* a *fan-out-then-reply* leg (Chosen to replicas, the owner replies)
  averages over the uniformly-assigned responder.

Per-region tensors come from iterating the actual closed-loop client
population: client ``i`` lives in ``geo.client_region(i, n_clients)``
and enters the cluster at entry replica ``i % entry_count`` - the same
deterministic routing the deployments use - then latencies average
within each region.  Regions that host no client report the expectation
over entry points (what a client placed there *would* see).

The models here are failure-free and queueing-free: pure wire time.
:meth:`repro.core.sweep.CompiledSweep.geo_latency` composes these WAN
offsets with the jitted MVA queueing curves into the (config x region)
latency surface; :func:`repro.core.execution.validate_variant` checks
them against real measured cluster latency per region.

Stdlib-only on purpose: the docs-link checker imports this module
without jax/numpy installed, and the execution plane must not grow a
jax dependency.

Adding a variant: :func:`register_geo_path` installs a
``(config, geo, n_clients) -> (write[R], read[R])`` lowering under the
variant's name - runtime-registered variants join the geo plane with
zero edits here, same contract as the demand-table registry.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .api import GeoSpec, Workload, resolve_workload, variant_spec

Config = Dict[str, Any]

# (config, geo, n_clients) -> (per-region write latency, per-region read
# latency), both length geo.n_regions
GeoPathFn = Callable[[Config, GeoSpec, int], Tuple[List[float], List[float]]]

_GEO_PATHS: Dict[str, GeoPathFn] = {}


def register_geo_path(name: str, fn: GeoPathFn) -> None:
    """Install (or replace) a variant's critical-path lowering."""
    _GEO_PATHS[name] = fn


def geo_variants() -> Tuple[str, ...]:
    """Variants with a registered critical-path latency lowering."""
    return tuple(_GEO_PATHS)


@dataclass(frozen=True)
class GeoLatency:
    """Per-region critical-path wire latency of one deployment.

    ``write[r]`` / ``read[r]`` are the expected commit / read latencies
    (virtual time units) seen by a client in region ``r``; variants that
    execute reads through the write path (``reads_as_writes``) report
    ``read == write``.
    """

    variant: str
    regions: Tuple[str, ...]
    write: Tuple[float, ...]
    read: Tuple[float, ...]

    def blended(self, workload: Optional[Union[Workload, float]] = None,
                f_write: Optional[float] = None) -> Tuple[float, ...]:
        """Mix write/read latency at a workload's write fraction."""
        w = resolve_workload(workload, f_write, where="GeoLatency.blended")
        return tuple(w.f_write * wr + w.f_read * rd
                     for wr, rd in zip(self.write, self.read))


# ---------------------------------------------------------------------------
# hop algebra
# ---------------------------------------------------------------------------


def _rt(geo: GeoSpec, i: int, j: int) -> float:
    """Round trip between regions: there and back (2 local hops +
    full RTT).  ``i == j`` still costs two local hops - the wire goes
    through the network queue even for same-region (and self-addressed)
    sends."""
    return 2.0 * geo.local_delay + 2.0 * geo.one_way(i, j)


def _mean(xs) -> float:
    xs = list(xs)
    return sum(xs) / len(xs)


def _regions(geo: GeoSpec, kind: str, n: int) -> List[int]:
    return [geo.region_of(kind, i) for i in range(n)]


def _majority_quorums(n: int, k: int) -> List[Tuple[int, ...]]:
    return list(itertools.combinations(range(n), k))


def _grid_quorums(rows: int, cols: int
                  ) -> Tuple[List[Tuple[int, ...]], List[Tuple[int, ...]]]:
    """(write quorums, read quorums) of a row-major ``rows x cols`` grid:
    write quorums are columns, read quorums are rows - the same id
    layout as ``quorums.GridQuorums``."""
    writes = [tuple(r * cols + c for r in range(rows)) for c in range(cols)]
    reads = [tuple(r * cols + c for c in range(cols)) for r in range(rows)]
    return writes, reads


def _quorum_leg(geo: GeoSpec, src_region: int,
                quorums: Sequence[Tuple[int, ...]],
                member_regions: Sequence[int]) -> float:
    """E over a uniformly-picked quorum of the max round trip to its
    members: the sender unicasts to one quorum and waits for all of it
    (``pick_write_quorum`` / ``pick_read_quorum`` semantics)."""
    return _mean(max(_rt(geo, src_region, member_regions[m]) for m in q)
                 for q in quorums)


def _kth_smallest_rt(geo: GeoSpec, src_region: int,
                     member_regions: Sequence[int], k: int) -> float:
    """Broadcast to everyone, wait for the ``k`` fastest acks."""
    return sorted(_rt(geo, src_region, m) for m in member_regions)[k - 1]


def _per_region(geo: GeoSpec, n_clients: int, entry_count: int,
                lat: Callable[[int, int], Tuple[float, float]]
                ) -> Tuple[List[float], List[float]]:
    """Average ``lat(client_region, entry_index) -> (write, read)`` over
    the real client population: client ``i`` sits in
    ``client_region(i, n_clients)`` and enters at ``i % entry_count``.
    Regions hosting no client get the uniform-entry expectation."""
    sums_w = [0.0] * geo.n_regions
    sums_r = [0.0] * geo.n_regions
    counts = [0] * geo.n_regions
    for i in range(n_clients):
        rc = geo.client_region(i, n_clients)
        w, r = lat(rc, i % entry_count)
        sums_w[rc] += w
        sums_r[rc] += r
        counts[rc] += 1
    write: List[float] = []
    read: List[float] = []
    for rc in range(geo.n_regions):
        if counts[rc]:
            write.append(sums_w[rc] / counts[rc])
            read.append(sums_r[rc] / counts[rc])
        else:
            pairs = [lat(rc, e) for e in range(entry_count)]
            write.append(_mean(p[0] for p in pairs))
            read.append(_mean(p[1] for p in pairs))
    return write, read


def _reject_batching(cfg: Config, variant: str) -> None:
    if cfg.get("n_batchers", 0) or cfg.get("n_unbatchers", 0):
        raise ValueError(
            f"geo critical-path model for {variant!r} does not cover "
            "batched deployments: the batcher's FLUSH_AFTER timer adds "
            "latency the wire-time model cannot see")


def _acceptor_quorums(cfg: Config, f: int
                      ) -> Tuple[int, List[Tuple[int, ...]],
                                 List[Tuple[int, ...]]]:
    """(n_acceptors, write quorums, read quorums) for a grid config;
    the ``(2f+1, 1)`` grid lowers to majority quorums exactly like the
    compartmentalized deployment does."""
    rows = int(cfg.get("grid_rows", 2))
    cols = int(cfg.get("grid_cols", 2))
    if (rows, cols) == (2 * f + 1, 1):
        n = 2 * f + 1
        maj = _majority_quorums(n, f + 1)
        return n, maj, maj
    wq, rq = _grid_quorums(rows, cols)
    return rows * cols, wq, rq


def _preread(geo: GeoSpec, rc: int, read_quorums: Sequence[Tuple[int, ...]],
             acc: Sequence[int], reps: Sequence[int]) -> float:
    """The linearizable leaderless read: Preread round to one random
    read quorum, then a round trip to one random replica."""
    return (_quorum_leg(geo, rc, read_quorums, acc)
            + _mean(_rt(geo, rc, rep) for rep in reps))


def _ordered_tail(geo: GeoSpec, entry: int, rc: int,
                  prox: Sequence[int], wq: Sequence[Tuple[int, ...]],
                  acc: Sequence[int], reps: Sequence[int]) -> float:
    """The shared proxy-leader commit tail: entry station -> round-robin
    proxy -> Phase 2 quorum -> Chosen fan-out -> owning replica replies
    to the client.  ``prox`` empty means the sequencer self-broadcasts
    (no proxy hop)."""
    if not prox:
        return (_quorum_leg(geo, entry, wq, acc)
                + _mean(geo.hop_delay(entry, rep) + geo.hop_delay(rep, rc)
                        for rep in reps))
    return _mean(
        geo.hop_delay(entry, p)
        + _quorum_leg(geo, p, wq, acc)
        + _mean(geo.hop_delay(p, rep) + geo.hop_delay(rep, rc)
                for rep in reps)
        for p in prox)


# ---------------------------------------------------------------------------
# per-variant lowerings (mirror the deployments in protocols/mencius/
# spaxos/craq/bpaxos/iss - every leg here is a send the real cluster makes)
# ---------------------------------------------------------------------------


def _path_compartmentalized(cfg: Config, geo: GeoSpec, n_clients: int
                            ) -> Tuple[List[float], List[float]]:
    _reject_batching(cfg, "compartmentalized")
    f = int(cfg.get("f", 1))
    n_acc, wq, rq = _acceptor_quorums(cfg, f)
    n_prox = int(cfg.get("n_proxy_leaders", 10))
    n_rep = int(cfg.get("n_replicas", 4))
    acc = _regions(geo, "acceptor", n_acc)
    prox = _regions(geo, "proxy", n_prox)
    reps = _regions(geo, "replica", n_rep)
    leader = geo.region_of("leader", 0)

    def lat(rc: int, _e: int) -> Tuple[float, float]:
        write = (geo.hop_delay(rc, leader)
                 + _ordered_tail(geo, leader, rc, prox, wq, acc, reps))
        return write, _preread(geo, rc, rq, acc, reps)

    return _per_region(geo, n_clients, 1, lat)


def _path_multipaxos(cfg: Config, geo: GeoSpec, n_clients: int
                     ) -> Tuple[List[float], List[float]]:
    f = int(cfg.get("f", 1))
    n = 2 * f + 1
    wq = _majority_quorums(n, f + 1)
    acc = _regions(geo, "acceptor", n)
    reps = _regions(geo, "replica", n)
    leader = geo.region_of("leader", 0)

    def lat(rc: int, _e: int) -> Tuple[float, float]:
        w = (geo.hop_delay(rc, leader)
             + _ordered_tail(geo, leader, rc, (), wq, acc, reps))
        return w, w  # reads_as_writes

    return _per_region(geo, n_clients, 1, lat)


def _path_mencius(cfg: Config, geo: GeoSpec, n_clients: int
                  ) -> Tuple[List[float], List[float]]:
    f = int(cfg.get("f", 1))
    m = int(cfg.get("n_leaders", 3))
    n_acc, wq, rq = _acceptor_quorums(cfg, f)
    prox = _regions(geo, "proxy", int(cfg.get("n_proxy_leaders", 4)))
    acc = _regions(geo, "acceptor", n_acc)
    reps = _regions(geo, "replica", int(cfg.get("n_replicas", 3)))
    leaders = _regions(geo, "leader", m)

    def lat(rc: int, e: int) -> Tuple[float, float]:
        write = (geo.hop_delay(rc, leaders[e])
                 + _ordered_tail(geo, leaders[e], rc, prox, wq, acc, reps))
        return write, _preread(geo, rc, rq, acc, reps)

    return _per_region(geo, n_clients, m, lat)


def _path_vanilla_mencius(cfg: Config, geo: GeoSpec, n_clients: int
                          ) -> Tuple[List[float], List[float]]:
    f = int(cfg.get("f", 1))
    m = 2 * f + 1
    servers = _regions(geo, "server", m)

    def lat(rc: int, e: int) -> Tuple[float, float]:
        peers = [servers[j] for j in range(m) if j != e]
        quorums = _majority_quorums(m - 1, f + 1)
        phase2 = _mean(max(_rt(geo, servers[e], peers[j]) for j in q)
                       for q in quorums)
        # slot-order execution: after Phase 2 commits, the proposer still
        # waits for peers' skip/fill announcements (Chosen out, ChosenRange
        # back) before it may execute and reply; peer echoes overlap, so a
        # mean over peers tracks the measured wait
        skip_echo = _mean(_rt(geo, servers[e], p) for p in peers)
        w = _rt(geo, rc, servers[e]) + phase2 + skip_echo
        return w, w  # reads_as_writes; the proposing server itself replies

    return _per_region(geo, n_clients, m, lat)


def _path_spaxos(cfg: Config, geo: GeoSpec, n_clients: int
                 ) -> Tuple[List[float], List[float]]:
    f = int(cfg.get("f", 1))
    n_dis = int(cfg.get("n_disseminators", 2))
    n_stab = int(cfg.get("n_stabilizers", 3))
    n_acc, wq, rq = _acceptor_quorums(cfg, f)
    dis = _regions(geo, "disseminator", n_dis)
    stab = _regions(geo, "stabilizer", n_stab)
    prox = _regions(geo, "proxy", int(cfg.get("n_proxy_leaders", 3)))
    acc = _regions(geo, "acceptor", n_acc)
    reps = _regions(geo, "replica", int(cfg.get("n_replicas", 3)))
    leader = geo.region_of("leader", 0)
    maj = n_stab // 2 + 1

    def lat(rc: int, e: int) -> Tuple[float, float]:
        d = dis[e]
        # disseminate payload, wait for a stabilizer majority of acks
        stab_leg = _kth_smallest_rt(geo, d, stab, maj)
        # ordered id commit; the proxy routes Chosen(id) through a
        # round-robin stabilizer that resolves it to the payload before
        # the replica fan-out
        tail = _mean(
            geo.hop_delay(leader, p)
            + _quorum_leg(geo, p, wq, acc)
            + _mean(geo.hop_delay(p, st)
                    + _mean(geo.hop_delay(st, rep) + geo.hop_delay(rep, rc)
                            for rep in reps)
                    for st in stab)
            for p in prox)
        write = (geo.hop_delay(rc, d) + stab_leg
                 + geo.hop_delay(d, leader) + tail)
        return write, _preread(geo, rc, rq, acc, reps)

    return _per_region(geo, n_clients, n_dis, lat)


def _path_vanilla_spaxos(cfg: Config, geo: GeoSpec, n_clients: int
                         ) -> Tuple[List[float], List[float]]:
    f = int(cfg.get("f", 1))
    n = 2 * f + 1
    servers = _regions(geo, "server", n)
    maj = n // 2 + 1
    quorums = _majority_quorums(n, f + 1)

    def lat(rc: int, e: int) -> Tuple[float, float]:
        s = servers[e]
        # disseminate to all n (including a self-send, which still pays
        # two local hops through the queue), wait for a majority
        stab_leg = _kth_smallest_rt(geo, s, servers, maj)
        phase2 = _mean(max(_rt(geo, servers[0], servers[j]) for j in q)
                       for q in quorums)
        w = (geo.hop_delay(rc, s) + stab_leg
             + geo.hop_delay(s, servers[0]) + phase2
             + _mean(geo.hop_delay(servers[0], t) + geo.hop_delay(t, rc)
                     for t in servers))
        return w, w  # reads_as_writes

    return _per_region(geo, n_clients, n, lat)


def _path_craq(cfg: Config, geo: GeoSpec, n_clients: int
               ) -> Tuple[List[float], List[float]]:
    k = int(cfg.get("n_nodes", 3))
    chain = _regions(geo, "chain", k)

    def lat(rc: int, _e: int) -> Tuple[float, float]:
        # head-to-tail ChainWrite, tail-to-head ChainAck: one round trip
        # per adjacent pair, plus the client's trip to the head
        write = (_rt(geo, rc, chain[0])
                 + sum(_rt(geo, chain[i], chain[i + 1])
                       for i in range(k - 1)))
        # clean read at a uniformly-random chain node (the failure-free
        # closed loop keeps at most one write in flight, so the dirty
        # tail-forward path is rare - covered by the tolerance)
        read = _mean(_rt(geo, rc, c) for c in chain)
        return write, read

    return _per_region(geo, n_clients, 1, lat)


def _path_bpaxos(cfg: Config, geo: GeoSpec, n_clients: int
                 ) -> Tuple[List[float], List[float]]:
    n_prop = int(cfg.get("n_proposers", 3))
    n_dep = int(cfg.get("n_dep_nodes", 3))
    thrifty = bool(cfg.get("thrifty", False))
    props = _regions(geo, "proposer", n_prop)
    deps = _regions(geo, "dep_service", n_dep)
    reps = _regions(geo, "replica", int(cfg.get("n_replicas", 3)))
    q = n_dep // 2 + 1

    def lat(rc: int, e: int) -> Tuple[float, float]:
        pr = props[e]
        rts = [_rt(geo, pr, d) for d in deps]
        if thrifty:
            # unicast to a rotating q-window of dep nodes, wait for all
            dep_leg = _mean(max(rts[(s + j) % n_dep] for j in range(q))
                            for s in range(n_dep))
        else:
            # broadcast to all d, wait for the q fastest
            dep_leg = sorted(rts)[q - 1]
        w = (geo.hop_delay(rc, pr) + dep_leg
             + _mean(geo.hop_delay(pr, rep) + geo.hop_delay(rep, rc)
                     for rep in reps))
        return w, w  # reads execute through the dependency graph too

    return _per_region(geo, n_clients, n_prop, lat)


def _path_iss(cfg: Config, geo: GeoSpec, n_clients: int
              ) -> Tuple[List[float], List[float]]:
    f = int(cfg.get("f", 1))
    n_lead = int(cfg.get("n_leaders", 3))
    n_acc, wq, _rq = _acceptor_quorums(cfg, f)
    prox = _regions(geo, "proxy", int(cfg.get("n_proxy_leaders", 10)))
    acc = _regions(geo, "acceptor", n_acc)
    reps = _regions(geo, "replica", int(cfg.get("n_replicas", 4)))
    leaders = _regions(geo, "leader", n_lead)

    def lat(rc: int, e: int) -> Tuple[float, float]:
        le = leaders[e]
        # the command's bucket owner rotates per epoch; over the run each
        # leader owns ~1/L of the buckets, so forwarding costs one hop to
        # a uniformly-random owner (free when the entry leader owns it)
        w = geo.hop_delay(rc, le) + _mean(
            (0.0 if o == e else geo.hop_delay(le, leaders[o]))
            + _ordered_tail(geo, leaders[o], rc, prox, wq, acc, reps)
            for o in range(n_lead))
        return w, w  # reads ride the ordered path

    return _per_region(geo, n_clients, n_lead, lat)


def _path_unreplicated(cfg: Config, geo: GeoSpec, n_clients: int
                       ) -> Tuple[List[float], List[float]]:
    _reject_batching(cfg, "unreplicated")
    server = geo.region_of("server", 0)

    def lat(rc: int, _e: int) -> Tuple[float, float]:
        w = _rt(geo, rc, server)
        return w, w

    return _per_region(geo, n_clients, 1, lat)


for _name, _fn in (
    ("compartmentalized", _path_compartmentalized),
    ("multipaxos", _path_multipaxos),
    ("mencius", _path_mencius),
    ("vanilla_mencius", _path_vanilla_mencius),
    ("spaxos", _path_spaxos),
    ("vanilla_spaxos", _path_vanilla_spaxos),
    ("craq", _path_craq),
    ("bpaxos", _path_bpaxos),
    ("iss", _path_iss),
    ("unreplicated", _path_unreplicated),
):
    register_geo_path(_name, _fn)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def predict_geo_latency(config: Config, geo: GeoSpec,
                        n_clients: Optional[int] = None) -> GeoLatency:
    """Lower one config's message flow into per-region wire latency.

    ``n_clients`` defaults to the variant's registered executable client
    count so predictions line up with what ``run_variant`` measures."""
    variant = str(config.get("variant", "compartmentalized"))
    try:
        fn = _GEO_PATHS[variant]
    except KeyError:
        raise ValueError(
            f"variant {variant!r} has no registered geo path; choose from "
            f"{sorted(_GEO_PATHS)} or register_geo_path it") from None
    if n_clients is None:
        exe = variant_spec(variant).executable
        n_clients = exe.n_clients if exe is not None else 3
    write, read = fn(dict(config), geo, n_clients)
    return GeoLatency(variant=variant, regions=geo.regions,
                      write=tuple(write), read=tuple(read))


def zero_rtt(geo: GeoSpec) -> GeoSpec:
    """The same placement/weights/local_delay with an all-zero RTT
    matrix: the degenerate spec whose numbers must equal today's
    single-delay ones."""
    n = geo.n_regions
    return replace(geo, rtt=tuple((0.0,) * n for _ in range(n)))


def wan_offsets(config: Config, geo: GeoSpec,
                workload: Optional[Union[Workload, float]] = None,
                n_clients: Optional[int] = None) -> Tuple[float, ...]:
    """Per-region *extra* wire latency the WAN matrix adds on top of the
    uniform-delay baseline - the additive term
    :meth:`CompiledSweep.geo_latency` stacks onto the MVA queueing
    curves.  Exactly zero for a uniform matrix."""
    lat = predict_geo_latency(config, geo, n_clients=n_clients)
    base = predict_geo_latency(config, zero_rtt(geo), n_clients=n_clients)
    mixed = lat.blended(workload)
    base_mixed = base.blended(workload)
    return tuple(a - b for a, b in zip(mixed, base_mixed))


# which address kinds each variant places (the placement axis the
# autotuner searches); "replica" is the read/execute edge tier that hub
# placements deliberately leave spread
STATION_KINDS: Dict[str, Tuple[str, ...]] = {
    "compartmentalized": ("leader", "proxy", "acceptor", "replica"),
    "multipaxos": ("leader", "acceptor", "replica"),
    "mencius": ("leader", "proxy", "acceptor", "replica"),
    "vanilla_mencius": ("server",),
    "spaxos": ("leader", "proxy", "acceptor", "replica",
               "disseminator", "stabilizer"),
    "vanilla_spaxos": ("server",),
    "craq": ("chain",),
    "bpaxos": ("proposer", "dep_service", "replica"),
    "iss": ("leader", "proxy", "acceptor", "replica"),
    "unreplicated": ("server",),
}


def geo_station_kinds(variant: str) -> Tuple[str, ...]:
    """Address kinds a variant's placement can pin (registry-extensible
    via the STATION_KINDS mapping)."""
    try:
        return STATION_KINDS[variant]
    except KeyError:
        raise ValueError(
            f"variant {variant!r} has no registered station kinds; add it "
            "to repro.core.geo.STATION_KINDS") from None


def placement_candidates(variant: str, geo: GeoSpec
                         ) -> Dict[str, GeoSpec]:
    """The placement family ``autotune_placement`` searches:

    * ``spread`` - the default round-robin cycle (empty placement);
    * ``single/<region>`` - every station pinned to one region (remote
      clients pay the full client<->cluster WAN round trip);
    * ``hub/<region>`` - the ordering core pinned to one region but the
      replica tier spread, so read legs and commit fan-out average over
      nearby replicas (only distinct from ``single`` for variants with a
      separate replica tier).
    """
    kinds = geo_station_kinds(variant)
    out: Dict[str, GeoSpec] = {"spread": replace(geo, placement=())}
    hub_kinds = tuple(k for k in kinds if k != "replica")
    for r, name in enumerate(geo.regions):
        out[f"single/{name}"] = replace(
            geo, placement=tuple((k, (r,)) for k in kinds))
        if hub_kinds != kinds:
            out[f"hub/{name}"] = replace(
                geo, placement=tuple((k, (r,)) for k in hub_kinds))
    return out
