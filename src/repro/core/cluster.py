"""Deterministic in-process network + node base class.

Every protocol in ``repro.core`` runs on this substrate: nodes are Python
objects registered under string addresses; messages are delivered through a
virtual-time priority queue.  Everything is seeded and deterministic, which is
what makes the hypothesis property tests (linearizability under reordering,
drops and failures) reproducible.

Fault injection:
  * ``crash(addr)`` / ``recover(addr)``  - crashed nodes receive nothing.
  * ``partition(a, b)``                  - drop messages between groups.
  * ``drop_rate``                        - iid message drops (seeded).
  * per-link latency function            - reordering across links.

Timers: a node can call ``self.set_timer(name, delay, payload)``; the network
delivers a ``Timer`` message back to it at ``now + delay`` (cancelled if the
node crashed).
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from .messages import Timer


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    dst: str = field(compare=False)
    src: str = field(compare=False)
    msg: Any = field(compare=False)


class Node:
    """Base class for protocol roles."""

    def __init__(self, addr: str) -> None:
        self.addr = addr
        self.net: Optional["Network"] = None
        self.now: float = 0.0
        # message accounting (used by message-count benchmarks)
        self.msgs_received: int = 0
        self.msgs_sent: int = 0

    # -- wiring -------------------------------------------------------------
    def bind(self, net: "Network") -> None:
        self.net = net

    # -- messaging ----------------------------------------------------------
    def send(self, dst: str, msg: Any) -> None:
        assert self.net is not None
        self.msgs_sent += 1
        self.net.send(self.addr, dst, msg)

    def broadcast(self, dsts, msg: Any) -> None:
        for d in dsts:
            self.send(d, msg)

    def set_timer(self, name: str, delay: float, payload: Any = None) -> None:
        assert self.net is not None
        self.net.send(self.addr, self.addr, Timer(name, payload), delay=delay)

    # -- to override ----------------------------------------------------------
    def on_message(self, src: str, msg: Any) -> None:
        raise NotImplementedError

    def on_crash(self) -> None:  # state wiped unless the role persists it
        pass

    def on_recover(self) -> None:
        pass


class Network:
    """Virtual-time message bus with deterministic fault injection."""

    def __init__(
        self,
        seed: int = 0,
        default_latency: float = 1.0,
        jitter: float = 0.0,
        drop_rate: float = 0.0,
        latency_fn: Optional[Callable[[str, str], float]] = None,
    ) -> None:
        self.rng = random.Random(seed)
        self.nodes: Dict[str, Node] = {}
        self.queue: List[_Event] = []
        self.now: float = 0.0
        self._seq = itertools.count()
        self.default_latency = default_latency
        self.jitter = jitter
        self.drop_rate = drop_rate
        self.crashed: Set[str] = set()
        self.partitions: List[Tuple[Set[str], Set[str]]] = []
        self.delivered: int = 0
        self.dropped: int = 0
        # optional per-(src,dst) latency override (e.g. a GeoSpec's WAN
        # matrix).  Only consulted when `send` gets no explicit delay, so
        # self-addressed Timer deliveries (set_timer passes delay=) stay
        # local; jitter stacks on top of the matrix delay, never replaces
        # it.
        self.latency_fn = latency_fn

    # -- topology -------------------------------------------------------------
    def add_node(self, node: Node) -> Node:
        if node.addr in self.nodes:
            raise ValueError(f"duplicate address {node.addr}")
        self.nodes[node.addr] = node
        node.bind(self)
        return node

    def add_nodes(self, nodes) -> None:
        for n in nodes:
            self.add_node(n)

    # -- fault injection --------------------------------------------------------
    def crash(self, addr: str) -> None:
        self.crashed.add(addr)
        self.nodes[addr].on_crash()

    def recover(self, addr: str) -> None:
        self.crashed.discard(addr)
        self.nodes[addr].on_recover()

    def partition(self, group_a, group_b) -> None:
        self.partitions.append((set(group_a), set(group_b)))

    def heal(self) -> None:
        self.partitions.clear()

    def _partitioned(self, src: str, dst: str) -> bool:
        for a, b in self.partitions:
            if (src in a and dst in b) or (src in b and dst in a):
                return True
        return False

    # -- send / deliver ---------------------------------------------------------
    def send(self, src: str, dst: str, msg: Any, delay: Optional[float] = None) -> None:
        if delay is None:
            if self.latency_fn is not None:
                delay = self.latency_fn(src, dst)
            else:
                delay = self.default_latency
            if self.jitter > 0:
                delay += self.rng.random() * self.jitter
        is_timer = isinstance(msg, Timer)
        if not is_timer:
            if self.drop_rate > 0 and self.rng.random() < self.drop_rate:
                self.dropped += 1
                return
            if self._partitioned(src, dst):
                self.dropped += 1
                return
        heapq.heappush(
            self.queue, _Event(self.now + delay, next(self._seq), dst, src, msg)
        )

    def step(self) -> bool:
        """Deliver the next message.  Returns False when the queue is empty."""
        while self.queue:
            ev = heapq.heappop(self.queue)
            self.now = ev.time
            if ev.dst in self.crashed or ev.dst not in self.nodes:
                self.dropped += 1
                continue
            if not isinstance(ev.msg, Timer) and self._partitioned(ev.src, ev.dst):
                self.dropped += 1
                continue
            node = self.nodes[ev.dst]
            node.now = ev.time
            # timers are local clock events, not network messages - keep
            # them out of the per-role message accounting the demand-table
            # parity checks are anchored on
            if not isinstance(ev.msg, Timer):
                node.msgs_received += 1
            node.on_message(ev.src, ev.msg)
            self.delivered += 1
            return True
        return False

    def run(self, max_steps: int = 1_000_000, until: Optional[float] = None) -> int:
        """Deliver messages until quiescence / step budget / time bound."""
        steps = 0
        while steps < max_steps:
            if until is not None and self.queue and self.queue[0].time > until:
                break
            if not self.step():
                break
            steps += 1
        return steps

    def pending(self) -> int:
        return len(self.queue)
