"""Deterministic synthetic LM data pipeline.

Design rule (elasticity): a batch is a *pure function* of (step, sample
index) - no per-worker iterator state.  After a membership change the new
workers recompute exactly their shard of the same global batch, so elastic
rescaling (runtime/coordinator.py) needs no data-state handoff; this is the
data-plane analogue of the paper's "replicas execute a deterministic log".

The token stream is a seeded order-1 Markov chain (so a model can actually
reduce loss on it), generated with numpy on the host; document packing with
loss masks is provided for variable-length corpora.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_concentration: float = 0.3  # lower = more predictable stream


class SyntheticLM:
    """Order-1 Markov token source with a fixed random transition kernel."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 512)  # kernel over a vocab subset
        self.v = v
        logits = rng.gumbel(size=(v, v)) / cfg.markov_concentration
        self.cum = np.cumsum(
            np.exp(logits - logits.max(-1, keepdims=True))
            / np.exp(logits - logits.max(-1, keepdims=True)).sum(-1, keepdims=True),
            axis=-1)

    def sample_sequence(self, step: int, index: int) -> np.ndarray:
        """Deterministic (step, index) -> tokens[seq_len + 1]."""
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 1_000_033 + index)
        n = self.cfg.seq_len + 1
        u = rng.random(n)
        toks = np.empty(n, np.int64)
        toks[0] = rng.integers(self.v)
        for t in range(1, n):
            toks[t] = np.searchsorted(self.cum[toks[t - 1]], u[t])
        return toks.astype(np.int32)

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.cfg.global_batch, self.cfg.seq_len
        seqs = np.stack([self.sample_sequence(step, i) for i in range(B)])
        return {"tokens": seqs[:, :S], "labels": seqs[:, 1:S + 1]}

    def shard_batch(self, step: int, rank: int, num_ranks: int
                    ) -> Dict[str, np.ndarray]:
        """The per-rank shard of the global batch (contiguous split)."""
        B = self.cfg.global_batch
        assert B % num_ranks == 0, (B, num_ranks)
        per = B // num_ranks
        lo = rank * per
        seqs = np.stack([self.sample_sequence(step, i)
                         for i in range(lo, lo + per)])
        S = self.cfg.seq_len
        return {"tokens": seqs[:, :S], "labels": seqs[:, 1:S + 1]}


# ---------------------------------------------------------------------------
# document packing
# ---------------------------------------------------------------------------


def pack_documents(docs: List[np.ndarray], seq_len: int, pad_id: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Greedy-pack variable-length documents into fixed windows.

    Returns (tokens [N, seq_len], loss_mask [N, seq_len], segment_ids
    [N, seq_len]); loss is masked at padding; segment ids let attention
    masks avoid cross-document leakage."""
    rows: List[np.ndarray] = []
    masks: List[np.ndarray] = []
    segs: List[np.ndarray] = []
    cur: List[np.ndarray] = []
    cur_len = 0
    cur_seg: List[int] = []
    seg_id = 1

    def flush():
        nonlocal cur, cur_len, cur_seg
        if not cur:
            return
        toks = np.concatenate(cur)
        pad = seq_len - len(toks)
        rows.append(np.pad(toks, (0, pad), constant_values=pad_id))
        masks.append(np.pad(np.ones(len(toks)), (0, pad)))
        segs.append(np.pad(np.concatenate(
            [np.full(len(c), s) for c, s in zip(cur, cur_seg)]), (0, pad)))
        cur, cur_len, cur_seg = [], 0, []

    for doc in docs:
        doc = doc[:seq_len]
        if cur_len + len(doc) > seq_len:
            flush()
        cur.append(doc)
        cur_seg.append(seg_id)
        seg_id += 1
        cur_len += len(doc)
    flush()
    return (np.stack(rows).astype(np.int32),
            np.stack(masks).astype(np.float32),
            np.stack(segs).astype(np.int32))


# ---------------------------------------------------------------------------
# host prefetcher
# ---------------------------------------------------------------------------


class Prefetcher:
    """Background-thread batch prefetch (overlap host datagen with device
    compute; on a real pod this hides the host->HBM transfer)."""

    def __init__(self, source: SyntheticLM, rank: int, num_ranks: int,
                 depth: int = 2, start_step: int = 0) -> None:
        self.source = source
        self.rank, self.num_ranks = rank, num_ranks
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self.source.shard_batch(step, self.rank, self.num_ranks)
            batch["step"] = step
            try:
                self.q.put(batch, timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self, timeout: float = 30.0) -> Dict[str, np.ndarray]:
        return self.q.get(timeout=timeout)

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2.0)
