"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three terms in seconds (TPU v5e targets):

    compute    = HLO_FLOPs_per_device   / 197 TFLOP/s (bf16)
    memory     = HBM_bytes_per_device   / 819 GB/s
    collective = coll_bytes_per_device  /  50 GB/s ICI per link

(dry-run cost/collective numbers are already per-device - the compiled SPMD
module is the per-device program - so the "chips x" denominators of the
global form cancel.)

HBM bytes: the CPU backend's "bytes accessed" counts every op's operands
with *CPU* fusion choices - a gross upper bound on TPU HBM traffic (TPU
fuses elementwise chains into the matmuls).  The memory term therefore uses
a TPU-realistic analytical model, with the HLO number kept as
``memory_hlo_upper_s``:

    train:  read params (fwd + remat-refwd + bwd = 3x) + write params
            + read/write f32 moments (grad, m, v)        [argument+output
            bytes from memory_analysis cover params/opt/batch]
            + 3x residual-stream activation traffic (store fwd boundary,
            re-read at bwd, grad stream)
    decode: read params + KV cache once (weight streaming) + small writes
    prefill: read params + 2x activation stream + cache writes

Step-time estimate T = max(terms) (perfect overlap); the dominant term is
the bottleneck the perf loop iterates on.  We also report:

  * MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode),
  * usefulness  = MODEL_FLOPS / (HLO_FLOPs_per_device * n_devices)  - how
    much of compiled compute is "useful" (catches remat/redundancy waste;
    > 1 would mean XLA found algebraic savings, < 1 means overhead),
  * MFU_est     = MODEL_FLOPS / (n_devices * PEAK * T).

Caveat recorded in EXPERIMENTS.md: "bytes accessed" comes from the CPU
backend's HloCostAnalysis, which counts operand+result bytes per op with CPU
fusion choices - an upper bound on TPU HBM traffic.  A weight-streaming
lower bound (param + KV bytes once per step) is reported alongside.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12   # bf16 per chip (TPU v5e)
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    memory_hlo_upper_s: float = 0.0
    collective_s: float = 0.0
    weight_stream_s: float = 0.0
    dominant: str = ""
    step_s: float = 0.0
    model_flops: float = 0.0
    hlo_flops_global: float = 0.0
    usefulness: float = 0.0
    mfu_est: float = 0.0
    n_devices: int = 0
    note: str = ""
    tag: str = ""


def model_flops_for(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * cfg.n_active_params() * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * cfg.n_active_params() * tokens
    # decode: one token per sequence
    return 2.0 * cfg.n_active_params() * spec.global_batch


def _param_bytes(arch: str) -> float:
    cfg = get_config(arch)
    return cfg.n_params() * 2.0  # bf16


def _kv_bytes(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    if spec.kind != "decode":
        return 0.0
    per_layer = {"attn": spec.seq_len, "xattn": spec.seq_len,
                 "local_attn": min(cfg.attn_window or spec.seq_len,
                                   spec.seq_len)}
    total = 0.0
    for t in cfg.layer_types():
        if t in per_layer:
            total += (spec.global_batch * per_layer[t]
                      * cfg.n_kv_heads * cfg.head_dim * 2 * 2)  # K+V bf16
        elif t == "rwkv6":
            total += (spec.global_batch * cfg.n_heads
                      * cfg.head_dim * cfg.head_dim * 4)
        elif t == "rglru":
            total += spec.global_batch * cfg.rnn_width * 4
    return total


def _activation_bytes_per_device(arch: str, shape_name: str,
                                 n_dev: int) -> float:
    """Residual-stream activation traffic per device (remat policy: store
    one boundary tensor per layer; 3 touches for train, 2 for prefill)."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    if spec.kind == "decode":
        return 0.0
    # batch is sharded over the data axes; model axis keeps full tokens
    dp = max(n_dev // 16, 1)  # model axis is 16 wide on both meshes
    tokens_dev = spec.global_batch * spec.seq_len / dp
    touches = 3.0 if spec.kind == "train" else 2.0
    return touches * cfg.n_layers * tokens_dev * cfg.d_model * 2.0


def analyze_record(rec: dict) -> CellRoofline:
    cell = CellRoofline(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                        status=rec["status"], tag=rec.get("tag", ""))
    if rec["status"] != "ok":
        cell.note = rec.get("skip_reason", rec.get("error", ""))[:120]
        return cell
    n_dev = rec.get("n_devices", 256)
    cost = rec.get("cost_analysis", {})
    flops_dev = float(cost.get("flops", 0.0))
    bytes_hlo_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = sum(v["bytes"] for v in rec.get("collectives", {}).values())
    ma = rec.get("memory_analysis", {})
    arg_bytes = float(ma.get("argument_size_in_bytes", 0.0))
    out_bytes = float(ma.get("output_size_in_bytes", 0.0))
    # donated buffers alias inputs: count the traffic once
    out_bytes = max(out_bytes - float(ma.get("alias_size_in_bytes", 0.0)), 0.0)
    act_bytes = _activation_bytes_per_device(rec["arch"], rec["shape"], n_dev)

    cell.n_devices = n_dev
    cell.compute_s = flops_dev / PEAK_FLOPS
    cell.memory_s = (arg_bytes + out_bytes + act_bytes) / HBM_BW
    cell.memory_hlo_upper_s = bytes_hlo_dev / HBM_BW
    cell.collective_s = coll_dev / ICI_BW
    cell.weight_stream_s = ((_param_bytes(rec["arch"])
                             + _kv_bytes(rec["arch"], rec["shape"]))
                            / n_dev / HBM_BW)
    terms = {"compute": cell.compute_s, "memory": cell.memory_s,
             "collective": cell.collective_s}
    cell.dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    cell.step_s = max(terms.values())
    cell.model_flops = model_flops_for(rec["arch"], rec["shape"])
    cell.hlo_flops_global = flops_dev * n_dev
    cell.usefulness = (cell.model_flops / cell.hlo_flops_global
                       if cell.hlo_flops_global else 0.0)
    cell.mfu_est = (cell.model_flops / (n_dev * PEAK_FLOPS * cell.step_s)
                    if cell.step_s else 0.0)
    cell.note = _advice(cell)
    return cell


def _advice(cell: CellRoofline) -> str:
    if cell.dominant == "collective":
        return ("reduce TP activation all-reduces (sequence-parallel / "
                "DP-heavier layout / compressed cross-pod)")
    if cell.dominant == "memory":
        if cell.shape.startswith(("decode", "long")):
            return ("weight+KV streaming bound: raise batch per chip or "
                    "shrink cache dtype (int8 KV)")
        return "increase fusion / remat policy; raise arithmetic intensity"
    return "compute-bound: good placement; tune kernel tiling next"


def load_cells(results_dir: str, tag: str = "") -> List[CellRoofline]:
    cells = []
    for p in sorted(Path(results_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag", "") != tag:
            continue
        cells.append(analyze_record(rec))
    return cells


def markdown_table(cells: List[CellRoofline]) -> str:
    head = ("| arch | shape | mesh | compute s | memory s | collective s | "
            "dominant | T=max s | MFU_est | useful | note |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.status == "skipped":
            rows.append(f"| {c.arch} | {c.shape} | {c.mesh} | - | - | - | "
                        f"skipped | - | - | - | {c.note} |")
        elif c.status == "error":
            rows.append(f"| {c.arch} | {c.shape} | {c.mesh} | - | - | - | "
                        f"ERROR | - | - | - | {c.note} |")
        else:
            rows.append(
                f"| {c.arch} | {c.shape} | {c.mesh} "
                f"| {c.compute_s:.3e} | {c.memory_s:.3e} "
                f"| {c.collective_s:.3e} | **{c.dominant}** | {c.step_s:.3e} "
                f"| {c.mfu_est:.3f} | {c.usefulness:.2f} | {c.note} |")
    return head + "\n".join(rows) + "\n"


def pick_hillclimb_cells(cells: List[CellRoofline]) -> Dict[str, CellRoofline]:
    """The three assignment-mandated targets: worst roofline fraction, most
    collective-bound, most representative of the paper's technique."""
    ok = [c for c in cells if c.status == "ok" and c.mesh == "single"]
    # MFU is meaningful for token-dense cells; decode cells are judged by
    # bandwidth utilisation (weight streaming / step time)
    dense = [c for c in ok if c.shape.startswith(("train", "prefill"))]
    worst_mfu = min(dense or ok,
                    key=lambda c: c.mfu_est if c.mfu_est > 0 else 1e9)
    most_coll = max(ok, key=lambda c: (c.collective_s / max(c.step_s, 1e-30)))
    # the paper's technique = read/write path decoupling -> serving decode
    decode = [c for c in ok if c.shape.startswith(("decode", "long"))]
    representative = max(decode, key=lambda c: c.step_s) if decode else ok[0]
    return {"worst_mfu": worst_mfu, "most_collective": most_coll,
            "paper_representative": representative}
