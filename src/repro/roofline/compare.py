"""Compare roofline terms across policy variants of the same cell.

  PYTHONPATH=src python -m repro.roofline.compare \
      results/dryrun/granite-3-2b__train_4k__single*.json
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from .analysis import analyze_record


def describe(path: str) -> None:
    rec = json.loads(Path(path).read_text())
    c = analyze_record(rec)
    tag = rec.get("tag") or "baseline"
    if c.status != "ok":
        print(f"{tag:24s} {c.status}: {c.note}")
        return
    print(f"{tag:24s} compute={c.compute_s:9.3e}  memory={c.memory_s:9.3e}  "
          f"collective={c.collective_s:9.3e}  T={c.step_s:9.3e}  "
          f"dom={c.dominant:10s}  MFU={c.mfu_est:6.3f}  useful={c.usefulness:5.2f}")


def main() -> None:
    for p in sys.argv[1:]:
        describe(p)


if __name__ == "__main__":
    main()
