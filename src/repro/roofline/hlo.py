"""HLO parsing: collective-bytes accounting from a compiled SPMD module.

``compiled.as_text()`` is the post-partitioning per-device HLO, so every
shape is a per-shard shape and the sum below is **per-device** collective
bytes.  The roofline's collective term

    collective_bytes_global / (chips * link_bw)
  ==  collective_bytes_per_device / link_bw

so we divide per-device bytes by the per-chip ICI bandwidth directly.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

# e.g.  "bf16[2048,512]{1,0}"  or  "f32[]"
_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result of an HLO instruction: "  %name = <TYPE> op-name(...".  Async
# collectives appear as op-start/op-done; we count the -start (the -done
# carries the same payload and would double count).
_INSTR_RE = re.compile(
    r"=\s*(\(?[^)=]*?\)?)\s+(" + "|".join(COLLECTIVE_OPS)
    + r")(-start)?[\s(.]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective-op-kind: {count, bytes} (per-device result bytes)."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if f"{op}-done" in line:
            continue
        stats[op]["count"] += 1
        stats[op]["bytes"] += _type_bytes(type_str)
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
