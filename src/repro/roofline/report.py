"""Generate the EXPERIMENTS.md roofline section from dry-run artifacts.

  PYTHONPATH=src python -m repro.roofline.report [--results DIR] [--tag TAG]

Prints the markdown table + the three hillclimb picks; the EXPERIMENTS.md
sections are assembled from this output.
"""
from __future__ import annotations

import argparse
from pathlib import Path

from .analysis import load_cells, markdown_table, pick_hillclimb_cells

DEFAULT = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=str(DEFAULT))
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    args = ap.parse_args()

    cells = load_cells(args.results, tag=args.tag)
    if args.mesh:
        cells = [c for c in cells if c.mesh == args.mesh]
    print(markdown_table(cells))
    ok = [c for c in cells if c.status == "ok"]
    if ok:
        print("\n### Hillclimb targets\n")
        try:
            picks = pick_hillclimb_cells(cells)
            for k, c in picks.items():
                print(f"- **{k}**: {c.arch} x {c.shape} "
                      f"({c.dominant}-bound, MFU_est={c.mfu_est:.3f}, "
                      f"T={c.step_s:.3e}s) - {c.note}")
        except ValueError:
            pass
        print(f"\n{len(ok)} ok, "
              f"{sum(c.status == 'skipped' for c in cells)} skipped, "
              f"{sum(c.status == 'error' for c in cells)} errors")


if __name__ == "__main__":
    main()
